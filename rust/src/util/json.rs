//! Minimal JSON parser (in-tree substitute for `serde_json`, unavailable
//! offline — DESIGN.md §2). Only what the artifact manifest and config
//! files need: objects, arrays, strings, numbers, booleans, null. Strict
//! enough to reject malformed input with a positioned error.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.req("k")?.as_f64()`-style chains want anyhow-friendly errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// Serialize (stable key order — Obj is a BTreeMap).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_dump_parse() {
        let src = r#"{"model": {"layers": 4, "name": "tiny"}, "xs": [1.5, true, "s"]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("café é"));
    }

    #[test]
    fn real_manifest_parses() {
        // shape of the artifact manifest aot.py emits
        let src = r#"{
            "model": {"vocab": 256, "n_layers": 4},
            "weights": {"file": "weights.bin", "entries": [{"name": "w", "shape": [2, 3]}]},
            "executables": [{"kind": "prefill", "path": "p.hlo.txt", "seq_len": 16}]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("model").unwrap().req("n_layers").unwrap().as_usize(), Some(4));
        let e = &v.get("executables").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("kind").unwrap().as_str(), Some("prefill"));
    }
}
