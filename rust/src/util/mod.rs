//! In-tree substrates replacing crates unavailable in the offline build
//! (DESIGN.md §2): PRNG/distributions, JSON, statistics, property testing.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
pub use stats::{Histogram, Series};
