//! Tiny property-testing harness (in-tree substitute for `proptest`,
//! unavailable offline — DESIGN.md §2).
//!
//! Runs a property over N seeded random cases; on failure it reports the
//! failing seed so the case replays deterministically:
//!
//! ```ignore
//! prop(1000, |rng| {
//!     let len = rng.range_usize(1, 100);
//!     // ... build inputs, assert invariants ...
//! });
//! ```

use super::rng::Rng;

/// Number of cases can be overridden with LAYERKV_PROP_CASES.
pub fn default_cases(requested: usize) -> usize {
    std::env::var("LAYERKV_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(requested)
}

/// Run `body` for `cases` seeded cases. Panics (with the seed) on the first
/// failing case. `body` panicking is the failure signal, so plain `assert!`
/// works inside.
pub fn prop<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: usize, body: F) {
    let cases = default_cases(cases);
    let base = std::env::var("LAYERKV_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (replay with LAYERKV_PROP_SEED={base} \
                 LAYERKV_PROP_CASES={n}): {msg}",
                n = case + 1,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::sync::atomic::AtomicUsize::new(0);
        prop(50, |_rng| {
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        prop(50, |rng| {
            let x = rng.range(0, 10);
            assert!(x < 5, "x={x}");
        });
    }
}
