//! Deterministic PRNG + distributions (in-tree substitute for `rand` /
//! `rand_distr`, which are unavailable offline — DESIGN.md §2).
//!
//! xoshiro256++ core with helpers for the distributions the workload
//! generators need: uniform, exponential (Poisson inter-arrivals),
//! log-normal (ShareGPT-like length mixture) and categorical sampling.

/// xoshiro256++ deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range [{lo}, {hi})");
        // Lemire-style rejection-free-enough: multiply-shift (bias is
        // negligible for our span sizes; determinism is what matters).
        let span = hi - lo;
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// true with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Index sampled from unnormalised weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-component determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(17);
        let w = [1.0, 3.0];
        let mut counts = [0u32; 2];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        let frac = counts[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
