//! Summary statistics for latency series: mean, percentiles, histograms.

/// Accumulates samples and answers mean/percentile queries.
#[derive(Debug, Default, Clone)]
pub struct Series {
    samples: Vec<f64>,
    sorted: bool,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Smallest sample; 0.0 on an empty series — like `mean` and
    /// `percentile`, so a replica that completed nothing renders as `-`
    /// / 0 instead of poisoning report rollups with ±inf.
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; 0.0 on an empty series (see [`Series::min`]).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            // total_cmp: a single NaN sample (a degenerate record slipping
            // through an upstream metric) must not panic the whole report.
            // NaN sorts above +inf under the IEEE total order, so it lands
            // at the tail and only the percentiles that genuinely reach
            // into the tail ever see it.
            self.samples.sort_by(f64::total_cmp);
            self.sorted = true;
        }
        let n = self.samples.len();
        if n == 1 {
            return self.samples[0];
        }
        let rank = q / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi.min(n - 1)] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Fraction of samples strictly above a threshold.
    pub fn frac_above(&self, threshold: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().filter(|&&x| x > threshold).count() as f64
            / self.samples.len() as f64
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Fixed-bucket histogram (for reports / ASCII plots).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram { lo, hi, counts: vec![0; buckets], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.counts.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.counts[b.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut s = Series::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_series_is_zero() {
        let mut s = Series::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        // regression: these returned +inf / -inf on an empty series,
        // which leaked into zero-completion replica rows as NaN deltas
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert!(s.min().is_finite() && s.max().is_finite());
    }

    #[test]
    fn min_max_on_populated_series() {
        let mut s = Series::new();
        for x in [4.0, -2.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.min(), -2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn frac_above() {
        let mut s = Series::new();
        for i in 0..10 {
            s.push(i as f64);
        }
        assert!((s.frac_above(6.5) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn percentile_after_push_resorts() {
        let mut s = Series::new();
        s.push(5.0);
        assert_eq!(s.p50(), 5.0);
        s.push(1.0);
        s.push(9.0);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.counts, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn nan_sample_cannot_poison_percentiles() {
        // regression: sort_by(partial_cmp(..).expect("NaN sample"))
        // panicked the entire report when one record carried a NaN
        let mut s = Series::new();
        for i in 1..=99 {
            s.push(i as f64);
        }
        s.push(f64::NAN);
        // NaN sorts to the very tail under total_cmp: mid percentiles
        // stay finite and correct
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!(s.percentile(95.0).is_finite());
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        // only the extreme tail, which genuinely includes the bad
        // sample, reports it
        assert!(s.percentile(100.0).is_nan());
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Series::new();
        s.push(3.0);
        s.push(3.0);
        s.push(3.0);
        assert_eq!(s.std(), 0.0);
    }
}
