//! Arrival processes.

use crate::util::Rng;

/// Arrival time generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Poisson process with the given rate (req/s) — what the paper uses.
    Poisson { rate: f64 },
    /// Deterministic: one request every 1/rate seconds.
    Uniform { rate: f64 },
    /// Everything arrives at t=0 (offline/batch setting).
    Burst,
}

impl Arrivals {
    /// Generate `n` arrival timestamps (sorted, starting at ~0).
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0;
        for _ in 0..n {
            match self {
                Arrivals::Poisson { rate } => {
                    t += rng.exponential(*rate);
                }
                Arrivals::Uniform { rate } => {
                    t += 1.0 / rate;
                }
                Arrivals::Burst => {}
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_interarrival() {
        let mut rng = Rng::new(1);
        let ts = Arrivals::Poisson { rate: 4.0 }.generate(20_000, &mut rng);
        let mean = ts.last().unwrap() / 20_000.0;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn uniform_is_even() {
        let mut rng = Rng::new(1);
        let ts = Arrivals::Uniform { rate: 2.0 }.generate(4, &mut rng);
        assert_eq!(ts, vec![0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn burst_is_zero() {
        let mut rng = Rng::new(1);
        let ts = Arrivals::Burst.generate(3, &mut rng);
        assert_eq!(ts, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn sorted_nondecreasing() {
        let mut rng = Rng::new(9);
        let ts = Arrivals::Poisson { rate: 1.0 }.generate(1000, &mut rng);
        assert!(ts.windows(2).all(|w| w[1] >= w[0]));
    }
}
