//! Arrival processes.

use crate::util::Rng;

/// Arrival time generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Poisson process with the given rate (req/s) — what the paper uses.
    Poisson { rate: f64 },
    /// Deterministic: one request every 1/rate seconds.
    Uniform { rate: f64 },
    /// Everything arrives at t=0 (offline/batch setting).
    Burst,
    /// Bursty two-state (MMPP-style) on/off process: Poisson arrivals at
    /// `rate_on` during exponentially-distributed ON periods of mean
    /// `mean_on_s` seconds, silence during OFF periods of mean
    /// `mean_off_s`. Long-run mean rate is
    /// `rate_on * mean_on_s / (mean_on_s + mean_off_s)`, but arrivals
    /// clump into bursts — the skewed load that exposes state-blind
    /// request routing (and single-engine admission) to queueing spikes a
    /// plain Poisson trace at the same mean rate never produces.
    OnOff { rate_on: f64, mean_on_s: f64, mean_off_s: f64 },
    /// Diurnal inhomogeneous Poisson process: the instantaneous rate
    /// swings sinusoidally between `base_rate` (trough) and `peak_rate`
    /// (peak) over a `period_s`-second day, starting at the trough.
    /// Sampled exactly by thinning against the peak rate, so it stays a
    /// true Poisson process at every instant — the fleet-scale day/night
    /// load shape the `experiment fleet` sweeps drive (multi-hour traces
    /// where a whole shift of replicas idles through the trough).
    Diurnal { base_rate: f64, peak_rate: f64, period_s: f64 },
}

impl Arrivals {
    /// An on/off process with the given long-run mean rate: bursts at
    /// `burstiness`x the mean, 2-second mean ON sojourns with the OFF
    /// sojourn scaled so the duty cycle works out (short cycles, so even
    /// a few-hundred-request trace spans many burst/drain rounds rather
    /// than one mega-burst). `burstiness > 1`.
    pub fn bursty(mean_rate: f64, burstiness: f64) -> Arrivals {
        assert!(burstiness > 1.0, "burstiness must exceed 1 (got {burstiness})");
        // duty = mean_on / (mean_on + mean_off) = 1 / burstiness
        let mean_on_s = 2.0;
        let mean_off_s = mean_on_s * (burstiness - 1.0);
        Arrivals::OnOff { rate_on: mean_rate * burstiness, mean_on_s, mean_off_s }
    }

    /// Generate `n` arrival timestamps (sorted, starting at ~0).
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        if let Arrivals::OnOff { rate_on, mean_on_s, mean_off_s } = *self {
            return Self::generate_on_off(n, rate_on, mean_on_s, mean_off_s, rng);
        }
        if let Arrivals::Diurnal { base_rate, peak_rate, period_s } = *self {
            return Self::generate_diurnal(n, base_rate, peak_rate, period_s, rng);
        }
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0;
        for _ in 0..n {
            match self {
                Arrivals::Poisson { rate } => {
                    t += rng.exponential(*rate);
                }
                Arrivals::Uniform { rate } => {
                    t += 1.0 / rate;
                }
                Arrivals::Burst => {}
                Arrivals::OnOff { .. } | Arrivals::Diurnal { .. } => {
                    unreachable!("handled above")
                }
            }
            out.push(t);
        }
        out
    }

    /// The two-state chain: starts ON (burst-first — the worst case for a
    /// cold cluster), draws Poisson gaps at `rate_on`, and whenever a gap
    /// overruns the remaining ON sojourn, jumps the OFF period and starts
    /// a fresh ON sojourn.
    fn generate_on_off(
        n: usize,
        rate_on: f64,
        mean_on_s: f64,
        mean_off_s: f64,
        rng: &mut Rng,
    ) -> Vec<f64> {
        assert!(rate_on > 0.0 && mean_on_s > 0.0 && mean_off_s > 0.0);
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0;
        let mut on_left = rng.exponential(1.0 / mean_on_s);
        while out.len() < n {
            let gap = rng.exponential(rate_on);
            if gap <= on_left {
                on_left -= gap;
                t += gap;
                out.push(t);
            } else {
                // ON period expired before the next arrival: spend the
                // rest of it, sleep through OFF, start a new ON sojourn
                t += on_left + rng.exponential(1.0 / mean_off_s);
                on_left = rng.exponential(1.0 / mean_on_s);
            }
        }
        out
    }

    /// Exact thinning (Lewis–Shedler): draw candidate gaps from a
    /// homogeneous Poisson process at `peak_rate`, accept each candidate
    /// at `t` with probability `rate(t) / peak_rate`. The rate curve is
    /// `base + (peak - base) * (1 - cos(2πt/period)) / 2` — trough at
    /// t = 0 (a cold fleet ramping into the day), peak at half-period.
    fn generate_diurnal(
        n: usize,
        base_rate: f64,
        peak_rate: f64,
        period_s: f64,
        rng: &mut Rng,
    ) -> Vec<f64> {
        assert!(
            base_rate > 0.0 && peak_rate >= base_rate && period_s > 0.0,
            "diurnal needs 0 < base_rate <= peak_rate and a positive period"
        );
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0;
        while out.len() < n {
            t += rng.exponential(peak_rate);
            let phase = (std::f64::consts::TAU * t / period_s).cos();
            let rate = base_rate + (peak_rate - base_rate) * (1.0 - phase) * 0.5;
            if rng.f64() * peak_rate <= rate {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_interarrival() {
        let mut rng = Rng::new(1);
        let ts = Arrivals::Poisson { rate: 4.0 }.generate(20_000, &mut rng);
        let mean = ts.last().unwrap() / 20_000.0;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn uniform_is_even() {
        let mut rng = Rng::new(1);
        let ts = Arrivals::Uniform { rate: 2.0 }.generate(4, &mut rng);
        assert_eq!(ts, vec![0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn burst_is_zero() {
        let mut rng = Rng::new(1);
        let ts = Arrivals::Burst.generate(3, &mut rng);
        assert_eq!(ts, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn sorted_nondecreasing() {
        let mut rng = Rng::new(9);
        let ts = Arrivals::Poisson { rate: 1.0 }.generate(1000, &mut rng);
        assert!(ts.windows(2).all(|w| w[1] >= w[0]));
    }

    /// Squared coefficient of variation of the inter-arrival gaps: 1 for
    /// Poisson, >1 for anything burstier.
    fn cv2(ts: &[f64]) -> f64 {
        let gaps: Vec<f64> = ts.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        var / (mean * mean)
    }

    #[test]
    fn on_off_mean_rate_matches_duty_cycle() {
        let mut rng = Rng::new(21);
        // rate 8 during ON, 50% duty -> long-run mean 4 req/s
        let a = Arrivals::OnOff { rate_on: 8.0, mean_on_s: 4.0, mean_off_s: 4.0 };
        let ts = a.generate(40_000, &mut rng);
        let mean_rate = 40_000.0 / ts.last().unwrap();
        assert!((mean_rate - 4.0).abs() < 0.25, "mean_rate={mean_rate}");
        assert!(ts.windows(2).all(|w| w[1] >= w[0]));
        assert!(ts.iter().all(|t| t.is_finite() && *t >= 0.0));
    }

    #[test]
    fn on_off_is_burstier_than_poisson() {
        let mut rng = Rng::new(33);
        let poisson = Arrivals::Poisson { rate: 4.0 }.generate(20_000, &mut rng);
        let onoff = Arrivals::OnOff { rate_on: 16.0, mean_on_s: 2.0, mean_off_s: 6.0 }
            .generate(20_000, &mut rng);
        let (cp, co) = (cv2(&poisson), cv2(&onoff));
        assert!((cp - 1.0).abs() < 0.15, "poisson cv2={cp}");
        assert!(co > 1.5, "on/off cv2={co} must be clearly burstier than Poisson");
    }

    #[test]
    fn bursty_helper_hits_requested_mean() {
        let a = Arrivals::bursty(3.0, 2.0);
        match a {
            Arrivals::OnOff { rate_on, mean_on_s, mean_off_s } => {
                assert_eq!(rate_on, 6.0);
                let duty = mean_on_s / (mean_on_s + mean_off_s);
                assert!((rate_on * duty - 3.0).abs() < 1e-12);
            }
            other => panic!("expected OnOff, got {other:?}"),
        }
        let mut rng = Rng::new(5);
        let ts = a.generate(30_000, &mut rng);
        let mean_rate = 30_000.0 / ts.last().unwrap();
        assert!((mean_rate - 3.0).abs() < 0.2, "mean_rate={mean_rate}");
    }

    #[test]
    fn diurnal_mean_rate_between_base_and_peak() {
        let mut rng = Rng::new(7);
        let a = Arrivals::Diurnal { base_rate: 2.0, peak_rate: 10.0, period_s: 50.0 };
        let ts = a.generate(40_000, &mut rng);
        assert!(ts.windows(2).all(|w| w[1] >= w[0]));
        assert!(ts.iter().all(|t| t.is_finite() && *t >= 0.0));
        // long-run mean of the sinusoid is (base + peak) / 2 = 6 req/s
        let mean_rate = 40_000.0 / ts.last().unwrap();
        assert!((mean_rate - 6.0).abs() < 0.3, "mean_rate={mean_rate}");
    }

    #[test]
    fn diurnal_peak_half_period_outweighs_trough_half() {
        let mut rng = Rng::new(13);
        let period = 40.0;
        let a = Arrivals::Diurnal { base_rate: 1.0, peak_rate: 9.0, period_s: period };
        let ts = a.generate(20_000, &mut rng);
        // count arrivals landing in the peak-centred half of each day
        // (phase in [0.25, 0.75)) vs the trough-centred half
        let peak_half = ts
            .iter()
            .filter(|t| {
                let phase = (*t % period) / period;
                (0.25..0.75).contains(&phase)
            })
            .count();
        let trough_half = ts.len() - peak_half;
        assert!(
            peak_half as f64 > 2.0 * trough_half as f64,
            "peak_half={peak_half} trough_half={trough_half}"
        );
    }

    #[test]
    fn diurnal_deterministic_for_seed() {
        let a = Arrivals::Diurnal { base_rate: 1.5, peak_rate: 6.0, period_s: 30.0 };
        let x = a.generate(500, &mut Rng::new(19));
        let y = a.generate(500, &mut Rng::new(19));
        assert_eq!(x, y);
    }

    #[test]
    fn on_off_deterministic_for_seed() {
        let a = Arrivals::bursty(2.0, 3.0);
        let x = a.generate(500, &mut Rng::new(11));
        let y = a.generate(500, &mut Rng::new(11));
        assert_eq!(x, y);
    }
}
