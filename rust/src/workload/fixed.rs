//! Fixed-length workloads (Figs. 1, 4, 5): prompt length swept 128 -> 16k,
//! output pinned to 512, arrival rate 1 req/s, 100 requests.

use super::arrivals::Arrivals;
use super::{Trace, TraceRequest};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct FixedWorkload {
    pub prompt_len: usize,
    pub output_len: usize,
    pub n_requests: usize,
    pub arrivals: Arrivals,
}

impl FixedWorkload {
    /// The paper's Fig. 1/4 configuration at a given context length.
    pub fn paper(prompt_len: usize) -> Self {
        FixedWorkload {
            prompt_len,
            output_len: 512,
            n_requests: 100,
            arrivals: Arrivals::Poisson { rate: 1.0 },
        }
    }

    pub fn generate(&self, rng: &mut Rng) -> Trace {
        let times = self.arrivals.generate(self.n_requests, rng);
        let requests = times
            .into_iter()
            .enumerate()
            .map(|(id, arrival)| TraceRequest {
                id,
                arrival,
                prompt_len: self.prompt_len,
                output_len: self.output_len,
                prefix: Default::default(),
            })
            .collect();
        Trace { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape() {
        let mut rng = Rng::new(0);
        let t = FixedWorkload::paper(2048).generate(&mut rng);
        t.validate().unwrap();
        assert_eq!(t.len(), 100);
        assert!(t.requests.iter().all(|r| r.prompt_len == 2048 && r.output_len == 512));
    }
}
