//! Workload generation: the request traces the paper evaluates on.
//!
//! * fixed-length sweeps (Figs. 1, 4, 5): every prompt the same length,
//!   output fixed at 512 tokens, Poisson arrivals at 1 req/s;
//! * ShareGPT-like traces (Figs. 6-8): a synthetic mixture fitted to the
//!   reported ShareGPT range (4 - 2.3K tokens), Poisson arrivals swept
//!   over rates.

pub mod arrivals;
pub mod fixed;
pub mod session;
pub mod sharegpt;
pub mod trace;

pub use arrivals::Arrivals;
pub use session::SessionWorkload;

/// Content-addressed prefix identity of a request (multi-turn sessions,
/// shared system prompts). The default (all zeros) means "no shared
/// prefix" and leaves every engine path byte-identical to a trace that
/// never heard of prefix caching.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PrefixKey {
    /// Content hash of the reusable prefix (0 = none). Token ids are not
    /// modeled, so the hash *is* the content identity: two requests share
    /// a prefix iff their hashes match.
    pub hash: u64,
    /// Token length of that prefix (<= prompt_len; matching happens at
    /// block granularity, so only whole blocks of it can be reused).
    pub len: usize,
    /// Hash under which this request publishes its own context for
    /// successors when it completes (0 = publish nothing).
    pub publish: u64,
}

/// One request as the workload layer hands it to the engine.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceRequest {
    pub id: usize,
    /// Seconds since trace start.
    pub arrival: f64,
    pub prompt_len: usize,
    /// True output length (the engine stops there; the predictor only sees
    /// a noisy bucket of it).
    pub output_len: usize,
    /// Shared-prefix identity (zero = none; see [`PrefixKey`]).
    pub prefix: PrefixKey,
}

/// A full trace, sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Sanity: arrivals finite and sorted, ids unique and dense.
    pub fn validate(&self) -> Result<(), String> {
        for w in self.requests.windows(2) {
            if w[1].arrival < w[0].arrival {
                return Err(format!(
                    "arrivals out of order: {} after {}",
                    w[1].arrival, w[0].arrival
                ));
            }
        }
        for (i, r) in self.requests.iter().enumerate() {
            // a NaN arrival compares false on `<` both ways, so the
            // ordering sweep above can never catch it — reject every
            // non-finite arrival explicitly
            if !r.arrival.is_finite() {
                return Err(format!("non-finite arrival {} for request {}", r.arrival, r.id));
            }
            if r.id != i {
                return Err(format!("non-dense id {} at index {i}", r.id));
            }
            if r.prompt_len == 0 || r.output_len == 0 {
                return Err(format!("degenerate request {}", r.id));
            }
            if r.prefix.len > r.prompt_len {
                return Err(format!(
                    "request {}: prefix len {} exceeds prompt len {}",
                    r.id, r.prefix.len, r.prompt_len
                ));
            }
            if r.prefix.hash == 0 && r.prefix.len != 0 {
                return Err(format!(
                    "request {}: prefix len {} with no prefix hash",
                    r.id, r.prefix.len
                ));
            }
        }
        Ok(())
    }

    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.prompt_len + r.output_len).sum()
    }

    pub fn max_prompt_len(&self) -> usize {
        self.requests.iter().map(|r| r.prompt_len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_disorder() {
        let t = Trace {
            requests: vec![
                TraceRequest { id: 0, arrival: 1.0, prompt_len: 8, output_len: 8, ..Default::default() },
                TraceRequest { id: 1, arrival: 0.5, prompt_len: 8, output_len: 8, ..Default::default() },
            ],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_ids() {
        let t = Trace {
            requests: vec![TraceRequest { id: 3, arrival: 0.0, prompt_len: 8, output_len: 8, ..Default::default() }],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_non_finite_arrivals() {
        // regression: NaN compares false on `<`, so the ordering check
        // alone used to accept a NaN arrival anywhere in the trace
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let t = Trace {
                requests: vec![
                    TraceRequest { id: 0, arrival: 0.5, prompt_len: 8, output_len: 8, ..Default::default() },
                    TraceRequest { id: 1, arrival: bad, prompt_len: 8, output_len: 8, ..Default::default() },
                    TraceRequest { id: 2, arrival: 1.0, prompt_len: 8, output_len: 8, ..Default::default() },
                ],
            };
            assert!(t.validate().is_err(), "arrival {bad} must be rejected");
        }
        // a finite, sorted trace still validates
        let ok = Trace {
            requests: vec![
                TraceRequest { id: 0, arrival: 0.0, prompt_len: 8, output_len: 8, ..Default::default() },
                TraceRequest { id: 1, arrival: 0.0, prompt_len: 8, output_len: 8, ..Default::default() },
            ],
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_prefix_keys() {
        let mut t = Trace {
            requests: vec![TraceRequest {
                id: 0,
                arrival: 0.0,
                prompt_len: 8,
                output_len: 8,
                prefix: PrefixKey { hash: 7, len: 9, publish: 0 },
            }],
        };
        assert!(t.validate().is_err(), "prefix longer than the prompt");
        t.requests[0].prefix = PrefixKey { hash: 0, len: 4, publish: 0 };
        assert!(t.validate().is_err(), "prefix length without a hash");
        t.requests[0].prefix = PrefixKey { hash: 7, len: 8, publish: 9 };
        assert!(t.validate().is_ok());
    }
}
