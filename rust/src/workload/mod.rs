//! Workload generation: the request traces the paper evaluates on.
//!
//! * fixed-length sweeps (Figs. 1, 4, 5): every prompt the same length,
//!   output fixed at 512 tokens, Poisson arrivals at 1 req/s;
//! * ShareGPT-like traces (Figs. 6-8): a synthetic mixture fitted to the
//!   reported ShareGPT range (4 - 2.3K tokens), Poisson arrivals swept
//!   over rates.

pub mod arrivals;
pub mod fixed;
pub mod sharegpt;
pub mod trace;

pub use arrivals::Arrivals;

/// One request as the workload layer hands it to the engine.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub id: usize,
    /// Seconds since trace start.
    pub arrival: f64,
    pub prompt_len: usize,
    /// True output length (the engine stops there; the predictor only sees
    /// a noisy bucket of it).
    pub output_len: usize,
}

/// A full trace, sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Sanity: arrivals finite and sorted, ids unique and dense.
    pub fn validate(&self) -> Result<(), String> {
        for w in self.requests.windows(2) {
            if w[1].arrival < w[0].arrival {
                return Err(format!(
                    "arrivals out of order: {} after {}",
                    w[1].arrival, w[0].arrival
                ));
            }
        }
        for (i, r) in self.requests.iter().enumerate() {
            // a NaN arrival compares false on `<` both ways, so the
            // ordering sweep above can never catch it — reject every
            // non-finite arrival explicitly
            if !r.arrival.is_finite() {
                return Err(format!("non-finite arrival {} for request {}", r.arrival, r.id));
            }
            if r.id != i {
                return Err(format!("non-dense id {} at index {i}", r.id));
            }
            if r.prompt_len == 0 || r.output_len == 0 {
                return Err(format!("degenerate request {}", r.id));
            }
        }
        Ok(())
    }

    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.prompt_len + r.output_len).sum()
    }

    pub fn max_prompt_len(&self) -> usize {
        self.requests.iter().map(|r| r.prompt_len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_disorder() {
        let t = Trace {
            requests: vec![
                TraceRequest { id: 0, arrival: 1.0, prompt_len: 8, output_len: 8 },
                TraceRequest { id: 1, arrival: 0.5, prompt_len: 8, output_len: 8 },
            ],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_ids() {
        let t = Trace {
            requests: vec![TraceRequest { id: 3, arrival: 0.0, prompt_len: 8, output_len: 8 }],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_non_finite_arrivals() {
        // regression: NaN compares false on `<`, so the ordering check
        // alone used to accept a NaN arrival anywhere in the trace
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let t = Trace {
                requests: vec![
                    TraceRequest { id: 0, arrival: 0.5, prompt_len: 8, output_len: 8 },
                    TraceRequest { id: 1, arrival: bad, prompt_len: 8, output_len: 8 },
                    TraceRequest { id: 2, arrival: 1.0, prompt_len: 8, output_len: 8 },
                ],
            };
            assert!(t.validate().is_err(), "arrival {bad} must be rejected");
        }
        // a finite, sorted trace still validates
        let ok = Trace {
            requests: vec![
                TraceRequest { id: 0, arrival: 0.0, prompt_len: 8, output_len: 8 },
                TraceRequest { id: 1, arrival: 0.0, prompt_len: 8, output_len: 8 },
            ],
        };
        assert!(ok.validate().is_ok());
    }
}
