//! Multi-turn chat/RAG session workload — the shape the flat trace
//! model cannot express: a population of users sharing a handful of
//! long system prompts, each user holding a conversation whose turns
//! arrive after think-time gaps and whose prompt is the full prior
//! context plus a short new user message.
//!
//! Prefix identity is chained through [`PrefixKey`]:
//!
//! * turn 1 claims the population's shared system prompt
//!   (`hash = population hash`) and publishes its context back under the
//!   *population* hash — the first session to complete seeds the cache
//!   every later session's turn 1 hits;
//! * turn 2 still claims the population prefix (its own turn-1 context
//!   was published under the population hash) and publishes its full
//!   context under a session-chain hash;
//! * turns 3+ claim the previous turn's session-chain hash — full
//!   conversation-history reuse — and publish the chain forward.
//!
//! Arrivals are open-loop: turn t arrives a think-time gap after turn
//! t-1's *arrival* (the generator cannot know completions). Gaps default
//! to tens of seconds, so under sane load the predecessor has published
//! by the time its successor arrives; when it hasn't, the lookup simply
//! misses and the turn pays full prefill — conservation never depends on
//! hit rate.

use super::arrivals::Arrivals;
use super::{PrefixKey, Trace, TraceRequest};
use crate::util::Rng;

/// Hashes must survive a JSON round-trip through f64 (trace.rs), so the
/// generator masks them to 48 bits.
const HASH_MASK: u64 = (1 << 48) - 1;

/// splitmix64-style mix, masked to 48 bits and never 0 (0 means "no
/// prefix" in [`PrefixKey`]).
fn mix_hash(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    let h = (z ^ (z >> 31)) & HASH_MASK;
    h.max(1)
}

#[derive(Debug, Clone)]
pub struct SessionWorkload {
    /// Conversations to generate.
    pub n_sessions: usize,
    /// Distinct shared system prompts the sessions draw from.
    pub n_populations: usize,
    /// Tokens of each population's shared system prompt.
    pub shared_prefix_len: usize,
    /// Turns per session, uniform in [min, max].
    pub turns: (usize, usize),
    /// Tokens of each new user message, uniform in [min, max].
    pub user_len: (usize, usize),
    /// Tokens of each assistant reply, uniform in [min, max].
    pub output_len: (usize, usize),
    /// Mean think-time gap between a turn's arrival and the next (s),
    /// exponentially distributed.
    pub mean_think_s: f64,
    /// Session-start arrival process.
    pub arrivals: Arrivals,
}

impl SessionWorkload {
    /// A chat-assistant shape: long shared system prompts (the RAG/system
    /// context that dominates prefill), short user turns, short replies.
    pub fn chat(n_sessions: usize, rate: f64) -> Self {
        SessionWorkload {
            n_sessions,
            n_populations: 4,
            shared_prefix_len: 3072,
            turns: (3, 6),
            user_len: (32, 192),
            output_len: (48, 160),
            mean_think_s: 20.0,
            arrivals: Arrivals::Poisson { rate },
        }
    }

    /// Generate the interleaved trace: all sessions' turns merged, sorted
    /// by arrival, ids dense. Deterministic per seed.
    pub fn generate(&self, rng: &mut Rng) -> Trace {
        assert!(self.n_sessions > 0 && self.n_populations > 0);
        assert!(self.turns.0 >= 1 && self.turns.1 >= self.turns.0);
        assert!(self.user_len.0 >= 1 && self.user_len.1 >= self.user_len.0);
        assert!(self.output_len.0 >= 1 && self.output_len.1 >= self.output_len.0);
        assert!(self.mean_think_s > 0.0 && self.mean_think_s.is_finite());

        let starts = self.arrivals.generate(self.n_sessions, rng);
        let mut requests = Vec::new();
        for (sess, &start) in starts.iter().enumerate() {
            let pop = rng.range_usize(0, self.n_populations);
            let pop_hash = mix_hash(0x5E55, pop as u64);
            let n_turns = rng.range_usize(self.turns.0, self.turns.1 + 1);
            let mut arrival = start;
            // context the previous turn published (tokens), and its hash
            let mut chain_hash = 0u64;
            let mut chain_len = 0usize;
            for turn in 0..n_turns {
                let user = rng.range_usize(self.user_len.0, self.user_len.1 + 1);
                let output = rng.range_usize(self.output_len.0, self.output_len.1 + 1);
                let (hash, cached_len, base) = if turn <= 1 {
                    // turns 1-2 reuse the population's shared system
                    // prompt (turn 2's own history lives under the
                    // population hash too — see module docs)
                    let base = if turn == 0 {
                        self.shared_prefix_len
                    } else {
                        chain_len
                    };
                    (pop_hash, self.shared_prefix_len, base)
                } else {
                    (chain_hash, chain_len, chain_len)
                };
                let prompt_len = base + user;
                let publish = if turn == 0 {
                    pop_hash
                } else {
                    mix_hash(0xC0A1 + sess as u64, turn as u64)
                };
                requests.push(TraceRequest {
                    id: 0, // assigned after the global sort
                    arrival,
                    prompt_len,
                    output_len: output,
                    prefix: PrefixKey { hash, len: cached_len, publish },
                });
                chain_hash = publish;
                chain_len = prompt_len + output;
                arrival += rng.exponential(1.0 / self.mean_think_s);
            }
        }
        // merge the sessions into one arrival-ordered trace; total_cmp
        // (plus the insertion index for ties) keeps the order total and
        // deterministic
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by(|&a, &b| {
            requests[a]
                .arrival
                .total_cmp(&requests[b].arrival)
                .then(a.cmp(&b))
        });
        let mut sorted: Vec<TraceRequest> = order.into_iter().map(|i| requests[i].clone()).collect();
        for (i, r) in sorted.iter_mut().enumerate() {
            r.id = i;
        }
        let trace = Trace { requests: sorted };
        debug_assert!(trace.validate().is_ok(), "{:?}", trace.validate());
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_chained_trace() {
        let w = SessionWorkload::chat(12, 0.5);
        let t = w.generate(&mut Rng::new(3));
        t.validate().unwrap();
        assert!(t.len() >= 12 * 3 && t.len() <= 12 * 6);
        // every request claims and publishes a prefix
        assert!(t.requests.iter().all(|r| r.prefix.hash != 0));
        assert!(t.requests.iter().all(|r| r.prefix.publish != 0));
        // hashes survive the f64 JSON round-trip
        assert!(t
            .requests
            .iter()
            .all(|r| r.prefix.hash < (1 << 48) && r.prefix.publish < (1 << 48)));
        // later turns carry their whole history: some prompts must far
        // exceed the shared prefix + one user message
        let max = t.requests.iter().map(|r| r.prompt_len).max().unwrap();
        assert!(max > w.shared_prefix_len + w.user_len.1 + w.output_len.1);
    }

    #[test]
    fn shared_prefix_population_is_shared() {
        let w = SessionWorkload::chat(30, 1.0);
        let t = w.generate(&mut Rng::new(9));
        // first turns across sessions collapse onto <= n_populations hashes
        let mut pop_hashes: Vec<u64> = t
            .requests
            .iter()
            .filter(|r| r.prefix.len == w.shared_prefix_len)
            .map(|r| r.prefix.hash)
            .collect();
        assert!(!pop_hashes.is_empty());
        pop_hashes.sort_unstable();
        pop_hashes.dedup();
        assert!(pop_hashes.len() <= w.n_populations);
    }

    #[test]
    fn deterministic_for_seed() {
        let w = SessionWorkload::chat(20, 1.0);
        let a = w.generate(&mut Rng::new(77));
        let b = w.generate(&mut Rng::new(77));
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn think_time_spreads_turns() {
        let w = SessionWorkload::chat(5, 10.0);
        let t = w.generate(&mut Rng::new(21));
        // the trace must span at least a couple of think gaps
        let span = t.requests.last().unwrap().arrival - t.requests[0].arrival;
        assert!(span > w.mean_think_s, "span={span}");
    }
}
