//! Synthetic ShareGPT-like workload (Figs. 6-8).
//!
//! The real dataset is unavailable offline; the paper uses it purely as a
//! length/arrival distribution ("sequence length ranges from 4 to 2.3K
//! tokens", ChatGPT-3.5-era conversations). We fit a log-normal mixture to
//! the published ShareGPT statistics (vLLM paper §6.2: mean input ~161
//! tokens with a long tail, mean output ~338 tokens) and clamp to the
//! reported range — DESIGN.md §2 substitution table.

use super::arrivals::Arrivals;
use super::{Trace, TraceRequest};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ShareGptWorkload {
    pub n_requests: usize,
    pub arrivals: Arrivals,
    /// Clamp bounds (tokens) from the paper: 4 .. 2.3K.
    pub min_len: usize,
    pub max_len: usize,
}

impl ShareGptWorkload {
    pub fn paper(rate: f64, n_requests: usize) -> Self {
        ShareGptWorkload {
            n_requests,
            arrivals: Arrivals::Poisson { rate },
            min_len: 4,
            max_len: 2300,
        }
    }

    fn sample_prompt(&self, rng: &mut Rng) -> usize {
        // Mixture: 70% short chat turns (median ~60), 30% long pasted
        // context (median ~600). Log-normal tails reach the 2.3K cap.
        let (mu, sigma) = if rng.chance(0.7) { (4.1, 0.9) } else { (6.4, 0.7) };
        (rng.lognormal(mu, sigma) as usize).clamp(self.min_len, self.max_len)
    }

    fn sample_output(&self, rng: &mut Rng) -> usize {
        // Output lengths: median ~240 tokens, long tail (assistant answers).
        (rng.lognormal(5.5, 0.8) as usize).clamp(self.min_len, self.max_len)
    }

    pub fn generate(&self, rng: &mut Rng) -> Trace {
        let times = self.arrivals.generate(self.n_requests, rng);
        let requests = times
            .into_iter()
            .enumerate()
            .map(|(id, arrival)| TraceRequest {
                id,
                arrival,
                prompt_len: self.sample_prompt(rng),
                output_len: self.sample_output(rng),
                prefix: Default::default(),
            })
            .collect();
        Trace { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_within_paper_range() {
        let mut rng = Rng::new(0);
        let t = ShareGptWorkload::paper(4.0, 5000).generate(&mut rng);
        t.validate().unwrap();
        for r in &t.requests {
            assert!((4..=2300).contains(&r.prompt_len));
            assert!((4..=2300).contains(&r.output_len));
        }
    }

    #[test]
    fn distribution_moments_plausible() {
        let mut rng = Rng::new(7);
        let t = ShareGptWorkload::paper(4.0, 20_000).generate(&mut rng);
        let mean_in: f64 =
            t.requests.iter().map(|r| r.prompt_len as f64).sum::<f64>() / t.len() as f64;
        let mean_out: f64 =
            t.requests.iter().map(|r| r.output_len as f64).sum::<f64>() / t.len() as f64;
        // ShareGPT published stats: input ~161, output ~338 (we accept a
        // generous band — only the regime matters for the experiments)
        assert!((100.0..400.0).contains(&mean_in), "mean_in={mean_in}");
        assert!((200.0..500.0).contains(&mean_out), "mean_out={mean_out}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = ShareGptWorkload::paper(2.0, 100).generate(&mut Rng::new(5));
        let b = ShareGptWorkload::paper(2.0, 100).generate(&mut Rng::new(5));
        assert_eq!(a.requests, b.requests);
    }
}
