//! Trace import/export: save generated workloads and replay recorded ones
//! (JSON lines — one request per line), so experiments are reproducible
//! across machines and real request logs can be fed to the engine.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::{PrefixKey, Trace, TraceRequest};
use crate::util::Json;

/// Write a trace as JSON-lines: {"id":0,"arrival":0.13,"prompt_len":...}.
/// Prefix identity is only written when present, so prefix-free traces
/// keep the exact line format earlier versions emitted.
pub fn save(trace: &Trace, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    for r in &trace.requests {
        if r.prefix == PrefixKey::default() {
            writeln!(
                f,
                r#"{{"id":{},"arrival":{},"prompt_len":{},"output_len":{}}}"#,
                r.id, r.arrival, r.prompt_len, r.output_len
            )?;
        } else {
            writeln!(
                f,
                r#"{{"id":{},"arrival":{},"prompt_len":{},"output_len":{},"prefix_hash":{},"prefix_len":{},"publish_hash":{}}}"#,
                r.id,
                r.arrival,
                r.prompt_len,
                r.output_len,
                r.prefix.hash,
                r.prefix.len,
                r.prefix.publish
            )?;
        }
    }
    Ok(())
}

/// Load a JSON-lines trace; validates ordering/ids.
pub fn load(path: &Path) -> Result<Trace> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut requests = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), lineno + 1))?;
        // prefix fields are optional: traces written before prefix
        // caching (or without shared prefixes) simply omit them. Hashes
        // ride through f64 parsing, so generators keep them < 2^53
        // (SessionWorkload masks to 48 bits).
        let opt_u64 = |key: &str| -> u64 {
            j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
        };
        requests.push(TraceRequest {
            id: j.req("id")?.as_usize().context("id")?,
            arrival: j.req("arrival")?.as_f64().context("arrival")?,
            prompt_len: j.req("prompt_len")?.as_usize().context("prompt_len")?,
            output_len: j.req("output_len")?.as_usize().context("output_len")?,
            prefix: PrefixKey {
                hash: opt_u64("prefix_hash"),
                len: j.get("prefix_len").and_then(Json::as_usize).unwrap_or(0),
                publish: opt_u64("publish_hash"),
            },
        });
    }
    let trace = Trace { requests };
    trace.validate().map_err(|e| anyhow::anyhow!("invalid trace: {e}"))?;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::sharegpt::ShareGptWorkload;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("layerkv-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let t = ShareGptWorkload::paper(2.0, 50).generate(&mut Rng::new(3));
        save(&t, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(t.requests, back.requests);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_with_prefixes() {
        use crate::workload::SessionWorkload;
        let dir =
            std::env::temp_dir().join(format!("layerkv-trace-pfx-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sessions.jsonl");
        let t = SessionWorkload::chat(8, 1.0).generate(&mut Rng::new(4));
        assert!(t.requests.iter().any(|r| r.prefix.hash != 0));
        save(&t, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(t.requests, back.requests);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_lines() {
        let dir = std::env::temp_dir().join(format!("layerkv-trace-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\":0}\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load(&path).is_err());
        // out-of-order arrivals rejected by validation
        std::fs::write(
            &path,
            "{\"id\":0,\"arrival\":5.0,\"prompt_len\":8,\"output_len\":8}\n\
             {\"id\":1,\"arrival\":1.0,\"prompt_len\":8,\"output_len\":8}\n",
        )
        .unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load(Path::new("/nonexistent/trace.jsonl")).is_err());
    }
}
