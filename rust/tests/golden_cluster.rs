//! Golden cluster replay: a committed 4-replica bursty run
//! (tests/golden/cluster_bursty.jsonl — hand-written, deliberately NOT
//! produced by the workload generators, so it cannot drift with them)
//! replayed under every router against the frozen oracle path: every
//! replica in recompute-from-scratch mode with decode fast-forwarding
//! disabled. Router or lockstep changes that silently alter scheduling,
//! routing feedback, or the macro-stepping seam show up here as a
//! bit-level diff between the fast path and the oracle path.

use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
use layerkv::config::{Policy, ServingConfig};
use layerkv::workload::{trace, Trace};

fn golden_cluster_trace() -> Trace {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/cluster_bursty.jsonl");
    trace::load(&path).expect("committed golden cluster trace must load")
}

fn golden_cfg() -> ServingConfig {
    ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true })
}

#[test]
fn golden_cluster_fast_path_matches_frozen_oracle_under_every_router() {
    let tr = golden_cluster_trace();
    assert_eq!(tr.requests.len(), 48, "committed fixture changed shape");
    let cfg = golden_cfg();
    for router in RouterPolicy::ALL {
        let ccfg = ClusterConfig::homogeneous(&cfg, 4, *router);

        let mut fast = Cluster::new(&ccfg);
        // pin the mode explicitly: the ambient LAYERKV_MACRO default must
        // not decide whether this test exercises the macro-stepping seam
        fast.set_macro_steps(true);
        let out_fast = fast.run(&tr).expect("sim cluster never fails");

        let mut oracle = Cluster::new(&ccfg);
        oracle.use_recompute_oracle();
        let out_oracle = oracle.run(&tr).expect("sim cluster never fails");

        assert_eq!(
            out_fast.merged.records,
            out_oracle.merged.records,
            "router {}: fast path diverged from the frozen oracle",
            router.name()
        );
        assert_eq!(
            out_fast.merged.makespan.to_bits(),
            out_oracle.merged.makespan.to_bits(),
            "router {}: makespan bits diverge",
            router.name()
        );
        assert_eq!(out_fast.dropped, out_oracle.dropped, "router {}", router.name());
        assert_eq!(out_fast.per_replica.len(), 4);
        for (i, (a, b)) in
            out_fast.per_replica.iter().zip(&out_oracle.per_replica).enumerate()
        {
            assert_eq!(
                a.routed,
                b.routed,
                "router {}: replica {i} routing diverged",
                router.name()
            );
            assert_eq!(
                a.report.records, b.report.records,
                "router {}: replica {i} records diverged",
                router.name()
            );
            assert_eq!(
                &a.stats,
                &b.stats,
                "router {}: replica {i} engine stats diverged",
                router.name()
            );
        }
        // conservation on the fixture: every request comes back once
        assert_eq!(out_fast.accounted(), 48, "router {}", router.name());
    }
}

#[test]
fn golden_cluster_replay_is_deterministic() {
    // the fixture is a fixture: two fast-path replays are bit-identical
    let tr = golden_cluster_trace();
    let ccfg = ClusterConfig::homogeneous(&golden_cfg(), 4, RouterPolicy::SloAware);
    let run_once = || {
        let mut c = Cluster::new(&ccfg);
        c.set_macro_steps(true);
        c.run(&tr).unwrap()
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a.merged.records, b.merged.records);
    assert_eq!(a.merged.makespan.to_bits(), b.merged.makespan.to_bits());
    assert_eq!(a.dropped, b.dropped);
    for (x, y) in a.per_replica.iter().zip(&b.per_replica) {
        assert_eq!(x.routed, y.routed);
        assert_eq!(&x.stats, &y.stats);
    }
}

#[test]
fn golden_cluster_every_policy_serves_the_fixture() {
    // the committed trace stays a usable fixture for other suites: both
    // engine policies complete it on a 4-replica fleet without drops
    let tr = golden_cluster_trace();
    for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        let ccfg = ClusterConfig::homogeneous(&cfg, 4, RouterPolicy::KvPressure);
        let out = Cluster::new(&ccfg).run(&tr).unwrap();
        assert_eq!(out.merged.records.len(), 48, "{policy:?}");
        assert!(out.dropped.is_empty(), "{policy:?}");
    }
}
