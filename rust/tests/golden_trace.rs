//! Golden trace replay: a small committed trace (tests/golden/
//! trace_small.jsonl) served by the default LayerKV policy in the
//! two-tier configuration must reproduce the PRE-TENTPOLE engine
//! bit-for-bit — per-request TTFT/TPOT (via the full latency records),
//! makespan, and every stat counter. The committed oracle is
//! tests/support/reference_engine.rs, the verbatim pre-refactor engine
//! (do not edit it): whatever it produces on the committed trace IS the
//! expected output, so the expectation can never drift out of sync with
//! the cost model while still pinning pre-tentpole semantics.
//!
//! The replay also exercises the tier-transition log: in the two-tier
//! configuration every logged move must stay inside {GPU, host}, agree
//! with the engine's offload/onload counters, and be reproducible
//! run-to-run. Set `LAYERKV_GOLDEN_DUMP=/path/to/file` to write the
//! rendered log (bitwise timestamps + per-request latency lines) for
//! inspection or archival.

#[path = "support/reference_engine.rs"]
mod reference_engine;

use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::engine::run_trace_oracle;
use layerkv::coordinator::{run_trace, standard_predictor, Engine};
use layerkv::metrics::{TierTransition, TIER_DISK, TIER_GPU, TIER_HOST};
use layerkv::workload::{trace, Trace};

const ACC: f64 = 0.8;

fn golden_trace() -> Trace {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/trace_small.jsonl");
    trace::load(&path).expect("committed golden trace must load")
}

fn golden_cfg() -> ServingConfig {
    ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true })
}

fn render(log: &[TierTransition], rep: &layerkv::metrics::Report) -> String {
    let mut out = String::new();
    for r in &rep.records {
        out.push_str(&format!(
            "req={} ttft={:016x} tpot={:016x}\n",
            r.id,
            r.ttft().to_bits(),
            r.tpot().to_bits()
        ));
    }
    for t in log {
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[test]
fn golden_trace_replay_matches_pre_tentpole_oracle() {
    let tr = golden_trace();
    let cfg = golden_cfg();

    // the expected per-request TTFT/TPOT: the pre-tentpole oracle
    let (ref_rep, ref_stats) =
        reference_engine::run_trace_reference(cfg.clone(), &tr, ACC);
    assert_eq!(
        ref_rep.records.len(),
        tr.requests.len(),
        "oracle must serve the whole committed trace"
    );

    let mut e = Engine::new(cfg.clone(), standard_predictor(&tr, ACC));
    e.enable_transition_log();
    let rep = e.run(&tr);
    let stats = e.stats().clone();
    let log = e.take_transitions();

    // bit-identical latency records => bit-identical TTFT and TPOT
    assert_eq!(rep.records, ref_rep.records, "records diverge from the oracle");
    assert_eq!(rep.makespan.to_bits(), ref_rep.makespan.to_bits());
    for (a, b) in rep.records.iter().zip(&ref_rep.records) {
        assert_eq!(a.ttft().to_bits(), b.ttft().to_bits(), "req {} TTFT", a.id);
        assert_eq!(a.tpot().to_bits(), b.tpot().to_bits(), "req {} TPOT", a.id);
    }
    assert_eq!(
        (stats.steps, stats.prefill_steps, stats.decode_steps, stats.preemptions),
        (
            ref_stats.steps,
            ref_stats.prefill_steps,
            ref_stats.decode_steps,
            ref_stats.preemptions
        )
    );
    assert_eq!(
        (
            stats.proactive_offload_layers,
            stats.oom_forced_offload_layers,
            stats.onloaded_layers
        ),
        (
            ref_stats.proactive_offload_layers,
            ref_stats.oom_forced_offload_layers,
            ref_stats.onloaded_layers
        )
    );
    assert_eq!(stats.offload_bytes.to_bits(), ref_stats.offload_bytes.to_bits());

    // tier-transition log: two-tier runs never leave {GPU, host}, and the
    // log agrees with the counters
    assert!(
        !log.is_empty(),
        "LayerKV admits these prompts layer-wise; restores must appear in the log"
    );
    assert!(log.iter().all(|t| t.from != TIER_DISK && t.to != TIER_DISK));
    let count = |from: u8, to: u8| {
        log.iter().filter(|t| t.from == from && t.to == to).count() as u64
    };
    assert_eq!(
        count(TIER_GPU, TIER_HOST),
        stats.proactive_offload_layers + stats.oom_forced_offload_layers
    );
    assert_eq!(count(TIER_HOST, TIER_GPU), stats.onloaded_layers);
    assert!(log.windows(2).all(|w| w[0].t <= w[1].t), "log must be time-ordered");

    // replaying the committed trace reproduces the identical log + report
    let mut e2 = Engine::new(cfg, standard_predictor(&tr, ACC));
    e2.enable_transition_log();
    let rep2 = e2.run(&tr);
    assert_eq!(rep.records, rep2.records);
    assert_eq!(log, e2.take_transitions(), "transition log must be deterministic");

    if let Ok(path) = std::env::var("LAYERKV_GOLDEN_DUMP") {
        std::fs::write(&path, render(&log, &rep)).expect("golden dump");
    }
}

#[test]
fn golden_trace_oracle_mode_also_matches() {
    // the recompute-from-scratch engine mode must agree with the
    // pre-tentpole oracle on the committed trace too
    let tr = golden_trace();
    let cfg = golden_cfg();
    let (a, sa) = run_trace_oracle(cfg.clone(), &tr, ACC);
    let (b, sb) = reference_engine::run_trace_reference_oracle(cfg, &tr, ACC);
    assert_eq!(a.records, b.records);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!((sa.steps, sa.decode_steps), (sb.steps, sb.decode_steps));
}

#[test]
fn golden_trace_every_policy_completes_it() {
    // the committed trace is a fixture other suites can rely on: every
    // policy serves it without drops
    let tr = golden_trace();
    for policy in [
        Policy::Vllm,
        Policy::LayerKv { slo_aware: true },
        Policy::LayerKv { slo_aware: false },
    ] {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        let (rep, stats) = run_trace(cfg, &tr, ACC);
        assert_eq!(rep.records.len(), tr.requests.len(), "{policy:?}");
        assert!(stats.dropped.is_empty(), "{policy:?}");
    }
}
