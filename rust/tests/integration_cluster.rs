//! End-to-end cluster scenarios: the routing-policy payoff the `cluster`
//! experiment reports (KV-pressure / SLO-aware routing vs state-blind
//! round-robin under bursty ShareGPT-style load), heterogeneous-fleet
//! routing, and rejection accounting.

use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
use layerkv::config::{Policy, ServingConfig};
use layerkv::experiments as exp;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;

fn run_cluster(
    cfg: &ServingConfig,
    replicas: usize,
    router: RouterPolicy,
    trace: &layerkv::workload::Trace,
) -> (f64, f64, layerkv::cluster::ClusterReport) {
    let mut cluster = Cluster::new(&ClusterConfig::homogeneous(cfg, replicas, router));
    let out = cluster.run(trace).expect("sim cluster run");
    let mut ttft = out.merged.ttft();
    let p99 = ttft.p99();
    let viol = out.merged.slo_violation_rate(&cfg.slo);
    (p99, viol, out)
}

/// The acceptance scenario: on a bursty ShareGPT-style trace over >= 4
/// replicas, KV-pressure or SLO-aware routing strictly improves BOTH the
/// p99 TTFT and the SLO violation rate over round-robin. Round-robin is
/// state-blind: inside a burst it keeps feeding replicas that are already
/// drowning in long-prompt KV demand, re-creating the head-of-line
/// queueing LayerKV removed inside each engine.
#[test]
fn pressure_aware_routing_beats_round_robin_on_bursty_load() {
    let replicas = 4;
    let rate = exp::CLUSTER_RATE_PER_REPLICA * replicas as f64;
    // ~90 requests/replica at a 6-second on/off cycle; seed 23's draw
    // spans ~7 distinct burst/drain rounds at near-nominal mean rate —
    // transient overload the router can spread, not one mega-burst
    let trace = exp::cluster_trace(rate, 90 * replicas, 23);
    let cfg = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });

    let (rr_p99, rr_viol, rr_out) =
        run_cluster(&cfg, replicas, RouterPolicy::RoundRobin, &trace);
    let (kv_p99, kv_viol, _) =
        run_cluster(&cfg, replicas, RouterPolicy::KvPressure, &trace);
    let (slo_p99, slo_viol, _) =
        run_cluster(&cfg, replicas, RouterPolicy::SloAware, &trace);

    // the load must actually hurt round-robin, or "improvement" is vacuous
    assert!(
        rr_viol > 0.0,
        "bursty trace too light: round-robin violates nothing (p99 {rr_p99:.2}s)"
    );
    // round-robin itself must have balanced exactly (sanity that the
    // comparison is routing quality, not routing volume)
    for o in &rr_out.per_replica {
        assert_eq!(o.routed, 90);
    }

    let best_p99 = kv_p99.min(slo_p99);
    let best_viol = kv_viol.min(slo_viol);
    assert!(
        best_p99 < rr_p99,
        "pressure-aware routing must cut p99 TTFT: kv {kv_p99:.2}s / slo {slo_p99:.2}s \
         vs round-robin {rr_p99:.2}s"
    );
    assert!(
        best_viol < rr_viol,
        "pressure-aware routing must cut SLO violations: kv {:.1}% / slo {:.1}% \
         vs round-robin {:.1}%",
        100.0 * kv_viol,
        100.0 * slo_viol,
        100.0 * rr_viol
    );
}

/// Mixed fleet: one roomy replica, one starved replica (smaller KV pool).
/// KV-pressure routing reads the real pool aggregates and must shift load
/// toward the roomy replica; round-robin splits 50/50 regardless.
#[test]
fn kv_pressure_prefers_the_roomier_replica_in_a_mixed_fleet() {
    let roomy = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    let mut starved = roomy.clone();
    starved.gpu_mem_util = 0.45; // roughly a third of the roomy KV pool
    let trace = exp::cluster_trace(5.0, 120, 41);

    let ccfg = ClusterConfig {
        replicas: vec![roomy.clone(), starved],
        router: RouterPolicy::KvPressure,
        predictor_accuracy: 0.8,
    };
    let mut cluster = Cluster::new(&ccfg);
    let out = cluster.run(&trace).expect("sim cluster run");
    assert_eq!(out.accounted(), 120);
    let routed: Vec<usize> = out.per_replica.iter().map(|o| o.routed).collect();
    assert!(
        routed[0] > routed[1],
        "kv-pressure must favour the roomy replica, got {routed:?}"
    );
}

/// Requests no replica can ever serve are rejected (never silently lost),
/// and rejections stay conserved through the merge.
#[test]
fn cluster_accounts_rejections() {
    let mut cfg = ServingConfig::llama2_7b_tp1();
    cfg.max_model_len = 16384;
    cfg.max_batched_tokens = 20000;
    cfg.gpu_mem_util = 0.30; // pool below one 16k prompt's full-KV demand
    let trace = FixedWorkload {
        prompt_len: 16384,
        output_len: 32,
        n_requests: 6,
        arrivals: Arrivals::Poisson { rate: 1.0 },
    }
    .generate(&mut Rng::new(1));

    let mut cluster =
        Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::KvPressure));
    let out = cluster.run(&trace).expect("sim cluster run");
    assert_eq!(out.accounted(), 6);
    assert!(!out.dropped.is_empty(), "impossible prompts must be rejected");
    // drops carry global ids
    assert!(out.dropped.iter().all(|&id| id < 6));
}
