//! Integration tests over the REAL runtime path: artifacts -> PJRT ->
//! coordinator, including a numerical prefill/decode consistency check
//! executed entirely through the compiled HLO (no Python anywhere).
//!
//! All tests no-op with a note if `make artifacts` hasn't been run.

use std::rc::Rc;

use layerkv::config::Policy;
use layerkv::runtime::{
    argmax, artifacts, RealEngine, RealEngineConfig, RefModel, ServeRequest, TinyModel,
};

fn model() -> Option<TinyModel> {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(TinyModel::load(&dir).expect("artifact load"))
}

#[test]
fn prefill_decode_consistency_through_pjrt() {
    // prefill(prompt[..n]) + decode(prompt[n]) must equal prefill(prompt)
    // — the same invariant python/tests checks with jax, but here proven
    // on the AOT artifacts the serving path actually runs.
    let Some(m) = model() else { return };
    let cfg = m.art.model.clone();
    let prompt: Vec<i32> = (0..16).map(|i| (i * 13 + 5) % cfg.vocab as i32).collect();

    let full = m.prefill(&prompt).expect("full prefill");

    let part = m.prefill(&prompt[..15]).expect("partial prefill");
    // build decode caches [1, 2, KH, Smax, D] from the partial prefill
    let b = 1usize;
    let per_layer = b * 2 * cfg.n_kv_heads * cfg.max_seq * cfg.head_dim;
    let mut kvs: Vec<Vec<f32>> = (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect();
    for (layer, kv) in part.kv.iter().enumerate() {
        // [2, KH, 15, D] -> lane 0 of [1, 2, KH, Smax, D]
        for c in 0..2 {
            for h in 0..cfg.n_kv_heads {
                let src = (c * cfg.n_kv_heads + h) * kv.t * kv.d;
                let dst = ((c * cfg.n_kv_heads + h) * cfg.max_seq) * kv.d;
                kvs[layer][dst..dst + kv.t * kv.d]
                    .copy_from_slice(&kv.data[src..src + kv.t * kv.d]);
            }
        }
    }
    let out = m.decode(&[prompt[15]], &[15], &mut kvs).expect("decode");

    let max_err = full
        .logits
        .iter()
        .zip(&out.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "prefill/decode logits diverge: {max_err}");
    assert_eq!(argmax(&full.logits), argmax(&out.logits));
}

#[test]
fn prefill_bucket_padding_is_invisible() {
    // the same prompt through two different buckets must give the same
    // logits (causal masking hides the padding)
    let Some(m) = model() else { return };
    let prompt: Vec<i32> = (0..16).map(|i| (i * 7 + 3) % 256).collect();
    let small = m.prefill(&prompt).expect("16-bucket");
    assert_eq!(small.bucket, 16);
    let mut longer = prompt.clone();
    longer.push(1);
    let big = m.prefill(&longer).expect("32-bucket");
    assert_eq!(big.bucket, 32);
    // KV for the shared 16-token prefix must agree
    for (a, b) in small.kv.iter().zip(&big.kv) {
        let n = a.data.len().min(16 * a.d); // first head-plane rows
        let err = a.data[..n]
            .iter()
            .zip(&b.data[..n])
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-4, "prefix KV diverges across buckets: {err}");
    }
}

#[test]
fn batched_decode_matches_single_lane() {
    let Some(m) = model() else { return };
    let cfg = m.art.model.clone();
    let p1: Vec<i32> = (0..12).map(|i| (i * 3 + 1) % 256).collect();
    let p2: Vec<i32> = (0..20).map(|i| (i * 11 + 2) % 256).collect();
    let o1 = m.prefill(&p1).unwrap();
    let o2 = m.prefill(&p2).unwrap();

    let fill = |kv: &layerkv::runtime::LayerKv,
                buf: &mut [f32],
                lane: usize,
                b: usize| {
        let _ = b;
        for c in 0..2 {
            for h in 0..cfg.n_kv_heads {
                let src = (c * cfg.n_kv_heads + h) * kv.t * kv.d;
                let dst = (((lane * 2 + c) * cfg.n_kv_heads + h) * cfg.max_seq) * kv.d;
                buf[dst..dst + kv.t * kv.d].copy_from_slice(&kv.data[src..src + kv.t * kv.d]);
            }
        }
    };

    // batch of 2
    let b = 2usize;
    let per_layer = b * 2 * cfg.n_kv_heads * cfg.max_seq * cfg.head_dim;
    let mut kvs: Vec<Vec<f32>> = (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect();
    for (layer, (a, c)) in o1.kv.iter().zip(&o2.kv).enumerate() {
        fill(a, &mut kvs[layer], 0, b);
        fill(c, &mut kvs[layer], 1, b);
    }
    let both = m.decode(&[7, 9], &[12, 20], &mut kvs).unwrap();

    // single lanes
    let per1 = 2 * cfg.n_kv_heads * cfg.max_seq * cfg.head_dim;
    let mut kv1: Vec<Vec<f32>> = (0..cfg.n_layers).map(|_| vec![0.0; per1]).collect();
    for (layer, a) in o1.kv.iter().enumerate() {
        fill(a, &mut kv1[layer], 0, 1);
    }
    let solo1 = m.decode(&[7], &[12], &mut kv1).unwrap();

    let err = both.logits[..cfg.vocab]
        .iter()
        .zip(&solo1.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-3, "lane 0 diverges between batch sizes: {err}");
}

#[test]
fn real_engine_policies_agree_on_tokens() {
    // vLLM-style and LayerKV-style KV management must be numerically
    // invisible: same tokens out.
    let Some(_) = model() else { return };
    let dir = artifacts::default_dir();
    let jobs = |n: usize| -> Vec<ServeRequest> {
        (0..n)
            .map(|id| ServeRequest {
                id,
                prompt: (0..40 + id * 3).map(|i| ((id * 13 + i * 7) % 256) as i32).collect(),
                max_new_tokens: 6,
                arrival_s: 0.0,
            })
            .collect()
    };
    let mut outs = Vec::new();
    for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
        let mut engine = RealEngine::load(
            &dir,
            RealEngineConfig {
                device_kv_budget: 100 << 10,
                policy,
                max_batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let out = engine.serve(jobs(4)).unwrap();
        assert!(out.dropped.is_empty(), "{policy:?} dropped requests");
        outs.push(out.results.into_iter().map(|r| r.output).collect::<Vec<_>>());
    }
    assert_eq!(outs[0], outs[1], "policy must not change generated tokens");
}

// --- Engine<PjrtBackend> over the deterministic RefModel executor ------
//
// These run everywhere (no artifacts needed): the same coordinator +
// PjrtBackend code path as the PJRT tests above, with the in-process
// reference executor standing in for the compiled HLO.

fn ref_engine(policy: Policy, budget: usize) -> RealEngine<RefModel> {
    RealEngine::with_model(
        Rc::new(RefModel::new()),
        RealEngineConfig { device_kv_budget: budget, policy, max_batch: 8, ..Default::default() },
    )
}

/// One long prompt ahead of several short ones, all arriving at once.
fn hol_jobs() -> Vec<ServeRequest> {
    let mut jobs = vec![ServeRequest {
        id: 0,
        prompt: (0..64).map(|i| (i * 5 + 1) % 256).collect(),
        max_new_tokens: 6,
        arrival_s: 0.0,
    }];
    for id in 1..4 {
        jobs.push(ServeRequest {
            id,
            prompt: (0..16).map(|i| ((id * 13 + i * 3) % 256) as i32).collect(),
            max_new_tokens: 6,
            arrival_s: 0.0,
        });
    }
    jobs
}

/// The paper's Fig. 2 admission difference on a real multi-request
/// batch: under a device budget too small for the long prompt's FULL KV,
/// request-wise (vLLM) admission can never serve it — it is rejected —
/// while layer-wise (LayerKV) admission parks its KV on the host and
/// serves everything. The short requests' tokens agree across policies.
#[test]
fn vllm_rejects_what_layerwise_admission_serves() {
    // 16 KiB device budget = 8 layer-blocks of RefModel KV. The 64-token
    // prompt needs ceil(64/16) * 4 layers = 16 blocks fully-resident
    // (vLLM can never admit it); a 16-token prompt needs 4.
    let budget = 16 << 10;

    let mut v = ref_engine(Policy::Vllm, budget);
    let vout = v.serve(hol_jobs()).unwrap();
    assert_eq!(vout.dropped.len(), 1, "vLLM must reject the long prompt");
    assert_eq!(vout.dropped[0].0, 0);
    assert_eq!(vout.results.len(), 3);
    assert!(vout.results.iter().all(|r| r.id != 0));

    let mut l = ref_engine(Policy::LayerKv { slo_aware: true }, budget);
    let lout = l.serve(hol_jobs()).unwrap();
    assert!(lout.dropped.is_empty(), "LayerKV must serve the long prompt");
    assert_eq!(lout.results.len(), 4);
    assert!(
        l.kv_stats().offload_bytes > 0,
        "layer-wise admission must have parked KV on the host"
    );

    // KV management must be numerically invisible: the short requests'
    // tokens agree across policies, and the long one decodes fully.
    for r in &vout.results {
        let same = lout.results.iter().find(|x| x.id == r.id).unwrap();
        assert_eq!(r.output, same.output, "req {} tokens diverge", r.id);
        assert_eq!(r.output.len(), 6);
    }
    let long = lout.results.iter().find(|x| x.id == 0).unwrap();
    assert_eq!(long.output.len(), 6);
}

#[test]
fn refmodel_tokens_survive_any_budget() {
    // ample vs starved device budget: identical token streams
    let mut big = ref_engine(Policy::LayerKv { slo_aware: true }, 8 << 20);
    let mut tiny = ref_engine(Policy::LayerKv { slo_aware: true }, 2 << 10);
    let b = big.serve(hol_jobs()).unwrap();
    let t = tiny.serve(hol_jobs()).unwrap();
    assert_eq!(b.results.len(), t.results.len());
    for (x, y) in b.results.iter().zip(&t.results) {
        assert_eq!(x.output, y.output, "req {} tokens diverge across budgets", x.id);
    }
    assert!(tiny.kv_stats().offload_bytes > big.kv_stats().offload_bytes);
}

#[test]
fn paged_attn_artifact_executes() {
    let Some(m) = model() else { return };
    if !m.has_paged_kernel() {
        return;
    }
    let c = m.art.model.clone();
    let (b, pages, page, maxp) = (4usize, 64usize, 16usize, 16usize);
    let q = vec![0.25f32; b * c.n_heads * c.head_dim];
    let pool = vec![0.5f32; pages * 2 * c.n_kv_heads * page * c.head_dim];
    let table: Vec<i32> = (0..(b * maxp) as i32).map(|i| i % pages as i32).collect();
    let lens = vec![37i32, 1, 200, 64];
    let out = m
        .paged_attn(
            &q,
            &[b, c.n_heads, c.head_dim],
            &pool,
            &[pages, 2, c.n_kv_heads, page, c.head_dim],
            &table,
            &[b, maxp],
            &lens,
        )
        .unwrap();
    assert_eq!(out.len(), b * c.n_heads * c.head_dim);
    // uniform V = 0.5 -> attention output must be exactly 0.5 everywhere
    for &x in &out {
        assert!((x - 0.5).abs() < 1e-4, "paged attention over uniform V: {x}");
    }
}
