//! Integration tests over the simulation stack: workload -> scheduler ->
//! engine -> metrics, asserting the *shapes* the paper's evaluation
//! reports (who wins, in which regime) rather than absolute numbers.

use layerkv::config::{Policy, ServingConfig, SloTargets};
use layerkv::coordinator::run_trace;
use layerkv::metrics::Report;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn fixed(prompt: usize, out: usize, n: usize, rate: f64, seed: u64) -> Trace {
    FixedWorkload {
        prompt_len: prompt,
        output_len: out,
        n_requests: n,
        arrivals: Arrivals::Poisson { rate },
    }
    .generate(&mut Rng::new(seed))
}

fn run(policy: Policy, trace: &Trace) -> Report {
    let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
    run_trace(cfg, trace, 0.8).0
}

#[test]
fn fig1_shape_queueing_dominates_long_contexts() {
    // Paper Fig. 1: queueing fraction of TTFT grows with context length
    // and dominates at the long end.
    let short = run(Policy::Vllm, &fixed(256, 256, 40, 1.0, 3));
    let long = run(Policy::Vllm, &fixed(8192, 256, 40, 1.0, 3));
    let frac_short = short.queueing().mean() / short.ttft().mean().max(1e-9);
    let frac_long = long.queueing().mean() / long.ttft().mean().max(1e-9);
    assert!(frac_long > frac_short, "frac_long={frac_long} frac_short={frac_short}");
    assert!(frac_long > 0.5, "queueing must dominate at 8k: {frac_long}");
}

#[test]
fn fig1_shape_ttft_superlinear_tpot_mild() {
    let r1 = run(Policy::Vllm, &fixed(1024, 256, 40, 1.0, 5));
    let r2 = run(Policy::Vllm, &fixed(8192, 256, 40, 1.0, 5));
    let ttft_ratio = r2.ttft().mean() / r1.ttft().mean().max(1e-9);
    let tpot_ratio = r2.tpot().mean() / r1.tpot().mean().max(1e-9);
    // 8x the context: TTFT blows up far faster than TPOT
    assert!(ttft_ratio > 8.0, "ttft_ratio={ttft_ratio}");
    assert!(tpot_ratio < 4.0, "tpot_ratio={tpot_ratio}");
}

#[test]
fn fig4_shape_layerkv_wins_ttft_at_long_context_with_throughput_parity() {
    let trace = fixed(8192, 512, 50, 1.0, 7);
    let v = run(Policy::Vllm, &trace);
    let l = run(Policy::LayerKv { slo_aware: true }, &trace);
    let speedup = v.ttft().mean() / l.ttft().mean().max(1e-9);
    assert!(speedup > 2.0, "TTFT speedup {speedup:.2} too small at 8k");
    // P99 gap too
    assert!(v.ttft().p99() > l.ttft().p99());
    // throughput within ~15% (paper: <=3% on real hw; sim is coarser)
    let ratio = l.throughput_tok_s() / v.throughput_tok_s().max(1e-9);
    assert!((0.85..1.15).contains(&ratio), "tput ratio={ratio}");
}

#[test]
fn fig4_shape_parity_at_short_context() {
    let trace = fixed(256, 256, 40, 1.0, 9);
    let v = run(Policy::Vllm, &trace);
    let l = run(Policy::LayerKv { slo_aware: true }, &trace);
    let ratio = l.ttft().mean() / v.ttft().mean().max(1e-9);
    assert!((0.7..1.3).contains(&ratio), "short-context TTFT ratio={ratio}");
}

#[test]
fn fig5_shape_more_tp_less_ttft() {
    // Higher DoP scales compute and pools: absolute TTFT must fall.
    let trace = fixed(4096, 512, 30, 1.0, 11);
    let mut prev = f64::INFINITY;
    for tp in [2usize, 4, 8] {
        let mut cfg = ServingConfig::yi_34b_tp2().with_policy(Policy::LayerKv { slo_aware: true });
        cfg.tp = tp;
        let rep = run_trace(cfg, &trace, 0.8).0;
        let ttft = rep.ttft().mean();
        assert!(ttft < prev * 1.05, "tp={tp}: ttft={ttft} prev={prev}");
        prev = ttft;
    }
}

#[test]
fn fig6_shape_gap_widens_with_arrival_rate() {
    let mut gaps = Vec::new();
    for &rate in &[2.0, 8.0] {
        // queueing builds over time: the trace must be long enough to
        // reach the congested steady state at the high rate
        let trace = ShareGptWorkload::paper(rate, 350).generate(&mut Rng::new(13));
        let cfg = ServingConfig::llama2_7b_tp1();
        let v = run_trace(cfg.clone().with_policy(Policy::Vllm), &trace, 0.8).0;
        let l = run_trace(cfg.with_policy(Policy::LayerKv { slo_aware: true }), &trace, 0.8).0;
        gaps.push(v.ttft().mean() / l.ttft().mean().max(1e-9));
    }
    assert!(
        gaps[1] > gaps[0].max(1.0),
        "gap must widen with load: {gaps:?}"
    );
}

#[test]
fn fig8_shape_violation_ordering_under_load() {
    let slo = SloTargets { ttft_s: 3.0, tpot_s: 0.2 };
    let trace = ShareGptWorkload::paper(8.0, 400).generate(&mut Rng::new(17));
    let mut cfg = ServingConfig::llama2_7b_tp1();
    cfg.slo = slo;
    let v = run_trace(cfg.clone().with_policy(Policy::Vllm), &trace, 0.8).0;
    let l = run_trace(
        cfg.clone().with_policy(Policy::LayerKv { slo_aware: true }),
        &trace,
        0.8,
    )
    .0;
    let vv = v.slo_violation_rate(&slo);
    let lv = l.slo_violation_rate(&slo);
    assert!(
        lv < vv,
        "LayerKV violation rate {lv:.2} must undercut vLLM {vv:.2} at 7 req/s"
    );
}

#[test]
fn slo_ablation_no_slo_trades_tpot_for_ttft() {
    let trace = fixed(4096, 384, 40, 1.5, 19);
    let cfg = ServingConfig::llama2_7b_tp1();
    let l = run_trace(
        cfg.clone().with_policy(Policy::LayerKv { slo_aware: true }),
        &trace,
        0.8,
    )
    .0;
    let n = run_trace(
        cfg.with_policy(Policy::LayerKv { slo_aware: false }),
        &trace,
        0.8,
    )
    .0;
    // without the gate, TTFT is at least as good but TPOT is no better
    assert!(n.ttft().mean() <= l.ttft().mean() * 1.05);
    assert!(n.tpot().mean() >= l.tpot().mean() * 0.95);
}

#[test]
fn every_policy_conserves_requests() {
    for policy in
        [Policy::Vllm, Policy::LayerKv { slo_aware: true }, Policy::LayerKv { slo_aware: false }]
    {
        let trace = ShareGptWorkload::paper(4.0, 120).generate(&mut Rng::new(21));
        let cfg = ServingConfig::llama2_7b_tp1().with_max_model_len(4096).with_policy(policy);
        let (rep, stats) = run_trace(cfg, &trace, 0.8);
        assert_eq!(
            rep.records.len() + stats.dropped.len(),
            trace.len(),
            "{}: requests lost",
            policy.name()
        );
        for r in &rep.records {
            assert!(r.prefill_start >= r.arrival - 1e-9, "{}: time travel", policy.name());
            assert!(r.first_token >= r.prefill_start);
            assert!(r.finish >= r.first_token);
            assert_eq!(r.output_len, trace.requests[r.id].output_len);
        }
    }
}

#[test]
fn preemption_only_happens_for_vllm() {
    let trace = fixed(8192, 512, 50, 1.5, 23);
    let cfg = ServingConfig::llama2_7b_tp1();
    let (_, sv) = run_trace(cfg.clone().with_policy(Policy::Vllm), &trace, 0.8);
    let (_, sl) = run_trace(cfg.with_policy(Policy::LayerKv { slo_aware: true }), &trace, 0.8);
    // LayerKV relieves pressure by offloading layers instead of recompute
    assert_eq!(sl.preemptions, 0, "LayerKV must not recompute-preempt");
    assert!(sl.offload_bytes > 0.0);
    let _ = sv; // vLLM may or may not preempt depending on timing
}
