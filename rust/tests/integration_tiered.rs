//! Integration tests for the GPU -> host -> disk KV hierarchy: a workload
//! sized so host RAM saturates must engage the disk spill tier, still
//! complete every request at bounded TTFT, and beat a no-disk baseline
//! that can only reject (the tiered analog of the HOL-blocking test).

use layerkv::config::{DiskSpec, Policy, ServingConfig};
use layerkv::coordinator::{run_trace, standard_predictor, Engine};
use layerkv::experiments::tier_sweep_with;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::Trace;

/// Long-prompt workload whose host-KV demand (~0.5 GB per request at 4k
/// tokens) saturates a 1 GB host swap pool immediately.
fn saturating_trace(n: usize) -> Trace {
    FixedWorkload {
        prompt_len: 4096,
        output_len: 64,
        n_requests: n,
        arrivals: Arrivals::Poisson { rate: 1.0 },
    }
    .generate(&mut Rng::new(23))
}

fn starved_cfg() -> ServingConfig {
    let mut cfg =
        ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true });
    cfg.cpu_swap_bytes = 1 << 30; // 1 GB host swap: < one prompt's L-x layers
    cfg
}

#[test]
fn host_pressure_spills_to_disk_requests_complete_ttft_bounded() {
    let n = 8;
    let trace = saturating_trace(n);

    // no-disk baseline: the host pool cannot hold even one request's
    // non-retained layers -> every long prompt is rejected
    let (base_rep, base_stats) = run_trace(starved_cfg(), &trace, 0.8);
    assert_eq!(
        base_stats.dropped.len(),
        n,
        "starved two-tier baseline must reject the saturating workload"
    );
    assert!(base_rep.records.is_empty());

    // same host pool + a disk tier: spill engages and everything is served
    let cfg = starved_cfg().with_disk(DiskSpec::nvme_4tb());
    let mut e = Engine::new(cfg, standard_predictor(&trace, 0.8));
    let rep = e.run(&trace);
    let stats = e.stats().clone();
    assert_eq!(rep.records.len(), n, "disk tier must serve every request");
    assert!(stats.dropped.is_empty());
    assert!(stats.spill_bytes > 0.0, "host saturation must engage disk spill");
    assert!(
        stats.disk_promoted_layers > 0 || stats.disk_stream_bytes > 0.0,
        "disk-resident layers must be read back to decode"
    );

    // TTFT stays bounded: admission is layer-wise (x solved against both
    // links), so first tokens come at ~prefill latency, not at
    // drain-the-queue latency
    let ttft_mean = rep.ttft().mean();
    assert!(ttft_mean < 10.0, "mean TTFT {ttft_mean}s must stay bounded under spill");
    assert!(rep.queueing().mean() < 10.0);

    // conservation after the run: every tier drains
    assert_eq!(e.kv.gpu.used(), 0);
    assert_eq!(e.kv.cpu.used(), 0);
    assert_eq!(e.kv.disk.used(), 0);
}

#[test]
fn deeper_disk_tiers_monotonically_reduce_rejections() {
    let rows = tier_sweep_with(12);
    assert_eq!(rows.len(), 4);
    let baseline = &rows[0];
    assert_eq!(baseline.disk_gb, 0);
    assert!(
        baseline.rejected > 0,
        "host-only baseline must reject under host-saturating load"
    );
    assert_eq!(baseline.spill_mb, 0.0, "no disk tier, no spill traffic");
    // every disk-equipped row serves more and spills
    for r in &rows[1..] {
        assert!(
            r.rejected < baseline.rejected,
            "disk {} GB: rejected {} not below baseline {}",
            r.disk_gb,
            r.rejected,
            baseline.rejected
        );
        assert!(r.completed > baseline.completed);
        assert!(r.spill_mb > 0.0);
    }
    // rejections never increase as the disk tier grows
    for w in rows[1..].windows(2) {
        assert!(w[1].rejected <= w[0].rejected);
    }
    // the largest tier serves everything
    let last = rows.last().unwrap();
    assert_eq!(last.rejected, 0, "512 GB disk tier must absorb the whole sweep");
}

#[test]
fn two_tier_and_tiered_agree_when_host_is_ample() {
    // ample host: the disk tier must not perturb a single bit of the
    // served schedule (integration-level spot check; the randomized
    // version lives in prop_invariants)
    let trace = saturating_trace(6);
    let base = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    let tiered = base.clone().with_disk(DiskSpec::nvme_4tb());
    let (a, sa) = run_trace(base, &trace, 0.8);
    let (b, sb) = run_trace(tiered, &trace, 0.8);
    assert_eq!(a.records, b.records);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(sa.steps, sb.steps);
    assert_eq!(sb.spilled_layers, 0);
    assert_eq!(sb.spill_bytes, 0.0);
}
