//! Cluster invariants (randomized, seeded, replayable via
//! LAYERKV_PROP_SEED / LAYERKV_PROP_CASES — see util::prop):
//!
//! * conservation — every trace request is routed to exactly one replica
//!   and comes back exactly once (as a completion or a rejection), under
//!   every router policy, replica count, and workload shape;
//! * 1-replica identity — a single-replica cluster is **bit-identical**
//!   to a bare `Engine<SimBackend>` run of the same trace, under every
//!   router (with one replica every policy routes identically, so the
//!   whole incremental `submit`/`step_once` drive must reproduce
//!   `try_run` exactly: records, makespan bits, and every engine
//!   counter).

use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::run_trace;
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn random_policy(rng: &mut Rng) -> Policy {
    match rng.range(0, 3) {
        0 => Policy::Vllm,
        1 => Policy::LayerKv { slo_aware: true },
        _ => Policy::LayerKv { slo_aware: false },
    }
}

fn random_trace(rng: &mut Rng, n: usize) -> Trace {
    let rate = rng.f64() * 4.0 + 0.5;
    let arrivals = if rng.chance(0.4) {
        Arrivals::bursty(rate, rng.f64() * 2.0 + 1.5)
    } else {
        Arrivals::Poisson { rate }
    };
    if rng.chance(0.5) {
        let mut w = ShareGptWorkload::paper(rate, n);
        w.arrivals = arrivals;
        w.generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals,
        }
        .generate(rng)
    }
}

#[test]
fn prop_every_request_routed_exactly_once() {
    prop(8, |rng| {
        let n = rng.range_usize(8, 40);
        let k = rng.range_usize(1, 6);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
        let out = cluster.run(&trace).expect("sim cluster never fails");

        // conservation across replicas: routed counts sum to the trace,
        // and completions + rejections partition the global id space
        assert_eq!(
            out.per_replica.iter().map(|o| o.routed).sum::<usize>(),
            n,
            "router {} on {k} replicas lost/duplicated a routing",
            router.name()
        );
        let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
        ids.extend(out.dropped.iter().copied());
        ids.sort_unstable();
        assert_eq!(
            ids,
            (0..n).collect::<Vec<_>>(),
            "router {} on {k} replicas: completions + drops must be a \
             permutation of the trace",
            router.name()
        );
        // per-replica accounting agrees with the merge
        assert_eq!(
            out.per_replica
                .iter()
                .map(|o| o.report.records.len() + o.stats.dropped.len())
                .sum::<usize>(),
            n
        );
        // causality on every merged record, against the *global* arrival
        for rec in &out.merged.records {
            let arrival = trace.requests[rec.id].arrival;
            assert!(rec.arrival == arrival, "merged record keeps its trace arrival");
            assert!(rec.prefill_start >= arrival - 1e-9);
            assert!(rec.first_token >= rec.prefill_start);
            assert!(rec.finish >= rec.first_token);
        }
    });
}

#[test]
fn prop_single_replica_cluster_bit_identical_to_bare_engine() {
    prop(6, |rng| {
        let n = rng.range_usize(5, 30);
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let (bare, bare_stats) = run_trace(cfg.clone(), &trace, 0.8);
        for router in RouterPolicy::ALL {
            let ccfg = ClusterConfig {
                replicas: vec![cfg.clone()],
                router: *router,
                predictor_accuracy: 0.8,
            };
            let mut cluster = Cluster::new(&ccfg);
            let out = cluster.run(&trace).expect("sim cluster never fails");
            assert_eq!(
                out.merged.records,
                bare.records,
                "router {}: records diverge from the bare engine",
                router.name()
            );
            assert_eq!(
                out.merged.makespan.to_bits(),
                bare.makespan.to_bits(),
                "router {}: makespan diverges",
                router.name()
            );
            // every engine counter identical — the incremental drive is
            // the same machine as try_run, not an approximation of it
            assert_eq!(
                &out.per_replica[0].stats,
                &bare_stats,
                "router {}: engine stats diverge",
                router.name()
            );
            assert_eq!(out.per_replica[0].routed, n);
        }
    });
}

/// Homogeneous replicas + round-robin on a uniform workload: the routed
/// counts are exactly balanced, and every replica's stats stay within the
/// single-engine regime (no replica sees a request the others' existence
/// could corrupt — replica isolation).
#[test]
fn prop_round_robin_balance_is_exact() {
    prop(6, |rng| {
        let k = rng.range_usize(2, 5);
        let per = rng.range_usize(3, 10);
        let n = k * per;
        let trace = FixedWorkload {
            prompt_len: rng.range_usize(64, 2048),
            output_len: rng.range_usize(8, 64),
            n_requests: n,
            arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.5 },
        }
        .generate(rng);
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, k, RouterPolicy::RoundRobin));
        let out = cluster.run(&trace).expect("sim cluster never fails");
        for o in &out.per_replica {
            assert_eq!(o.routed, per, "round-robin must balance {n} over {k} exactly");
            // replica-local ids are dense in submission order
            for rec in &o.report.records {
                assert!(rec.id < o.routed);
            }
        }
        let s = out.summary(&cfg.slo);
        assert!((s.max_share() - 1.0 / k as f64).abs() < 1e-12);
    });
}
