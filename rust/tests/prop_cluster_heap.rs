//! Cluster event-heap drive invariants (randomized, seeded, replayable
//! via LAYERKV_PROP_SEED / LAYERKV_PROP_CASES — see util::prop):
//!
//! * heap/lockstep bit-identity — the cluster-wide event-heap drive is
//!   **bit-identical** to the PR-6 virtual-time lockstep oracle across
//!   routers x macro-stepping x generated fault plans: merged records,
//!   makespan bits, drops, failures, fault summaries, rendered fault
//!   logs, per-replica routing, and every engine counter. The heap may
//!   change *when* each replica is advanced, never *what* any replica
//!   computes.
//! * O(total events) — the heap never issues more scheduler-bearing
//!   replica advances than lockstep, and on a wide mostly-idle fleet
//!   (32 replicas, bursty arrivals) it issues at least 5x fewer: the
//!   deterministic witness that fleet cost dropped from
//!   O(replicas x arrivals) to O(total events).

use layerkv::cluster::{Cluster, ClusterConfig, FaultPlan, RouterPolicy};
use layerkv::config::{Policy, ServingConfig};
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn random_policy(rng: &mut Rng) -> Policy {
    match rng.range(0, 3) {
        0 => Policy::Vllm,
        1 => Policy::LayerKv { slo_aware: true },
        _ => Policy::LayerKv { slo_aware: false },
    }
}

fn random_trace(rng: &mut Rng, n: usize) -> Trace {
    let rate = rng.f64() * 4.0 + 0.5;
    let arrivals = if rng.chance(0.4) {
        Arrivals::bursty(rate, rng.f64() * 2.0 + 1.5)
    } else {
        Arrivals::Poisson { rate }
    };
    if rng.chance(0.5) {
        let mut w = ShareGptWorkload::paper(rate, n);
        w.arrivals = arrivals;
        w.generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals,
        }
        .generate(rng)
    }
}

#[test]
fn prop_heap_drive_bit_identical_to_lockstep() {
    prop(8, |rng| {
        let n = rng.range_usize(8, 30);
        let k = rng.range_usize(2, 6);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let macro_steps = rng.chance(0.5);
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        // half the cases run under a generated fault schedule, with a
        // horizon slightly past the last arrival so events also land in
        // the drain phase (as in prop_faults)
        let plan = if rng.chance(0.5) {
            let horizon = trace
                .requests
                .last()
                .map(|r| r.arrival)
                .unwrap_or(0.0)
                .max(1.0);
            Some(FaultPlan::generate(rng.range(0, 1 << 30) as u64, k, horizon * 1.3))
        } else {
            None
        };
        let run = |lockstep: bool| {
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
            if let Some(p) = &plan {
                cluster = cluster.with_faults(p.clone());
            }
            cluster.set_lockstep(lockstep);
            cluster.set_macro_steps(macro_steps);
            let out = cluster.run(&trace).expect("sim cluster never fails");
            let log: Vec<String> =
                cluster.fault_log().iter().map(|e| e.render()).collect();
            (out, log, cluster.advances())
        };
        let (a, log_a, adv_heap) = run(false);
        let (b, log_b, adv_lock) = run(true);
        let label = format!(
            "router {} k={k} macro={macro_steps} faulted={}",
            router.name(),
            plan.is_some()
        );
        assert_eq!(a.merged.records, b.merged.records, "{label}: records");
        assert_eq!(
            a.merged.makespan.to_bits(),
            b.merged.makespan.to_bits(),
            "{label}: makespan bits"
        );
        assert_eq!(a.dropped, b.dropped, "{label}: drops");
        assert_eq!(a.failed, b.failed, "{label}: failures");
        assert_eq!(a.faults, b.faults, "{label}: fault summary");
        assert_eq!(log_a, log_b, "{label}: rendered fault log");
        for (pa, pb) in a.per_replica.iter().zip(&b.per_replica) {
            assert_eq!(pa.routed, pb.routed, "{label}: routing diverged");
            assert_eq!(
                pa.report.records, pb.report.records,
                "{label}: per-replica records diverged"
            );
            // every engine counter identical — the heap drive is the same
            // machine as lockstep, not an approximation of it
            assert_eq!(&pa.stats, &pb.stats, "{label}: engine stats diverged");
        }
        assert!(
            adv_heap <= adv_lock,
            "{label}: heap issued {adv_heap} advances, lockstep {adv_lock} — \
             the heap must never do more scheduler-bearing work"
        );
    });
}

/// Deterministic O(total events) witness: a wide, mostly-idle fleet under
/// bursty arrivals. Lockstep touches all 32 replicas at every arrival
/// (idle ones included — one blocked probe each); the heap never steps a
/// quiescent or mid-span replica, so its advance count collapses.
#[test]
fn heap_drive_advances_at_least_5x_fewer_on_bursty_fleet() {
    let cfg = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    let trace = FixedWorkload {
        prompt_len: 512,
        output_len: 128,
        n_requests: 128,
        arrivals: Arrivals::bursty(8.0, 3.0),
    }
    .generate(&mut Rng::new(29));
    let ccfg = ClusterConfig::homogeneous(&cfg, 32, RouterPolicy::KvPressure);
    let mut heap = Cluster::new(&ccfg);
    heap.set_lockstep(false);
    let a = heap.run(&trace).expect("sim cluster run");
    let mut lock = Cluster::new(&ccfg);
    lock.set_lockstep(true);
    let b = lock.run(&trace).expect("sim cluster run");
    // the speedup is measured between two bit-identical runs
    assert_eq!(a.merged.records, b.merged.records);
    assert_eq!(a.merged.makespan.to_bits(), b.merged.makespan.to_bits());
    assert!(
        heap.advances() * 5 <= lock.advances(),
        "heap drive issued {} scheduler-bearing advances vs lockstep {} — \
         expected >=5x fewer on a 32-replica bursty fleet",
        heap.advances(),
        lock.advances()
    );
}
