//! Decode fast-forward (macro-stepping) invariants: the macro-stepping
//! engine must be **bit-identical** to the single-step path — records,
//! makespan bits, every stat counter, tier-transition logs, and pool
//! state — on randomized traces and configs (two-tier, starved-host, and
//! three-tier shapes; all policies; bursty and Poisson arrivals; bare
//! engines and clusters), with the only visible difference being fewer
//! scheduler invocations. Randomized, seeded, replayable via
//! LAYERKV_PROP_SEED / LAYERKV_PROP_CASES (see util::prop); CI's
//! prop-deep job runs this suite at 512 cases.

use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
use layerkv::config::{DiskSpec, Policy, ServingConfig};
use layerkv::coordinator::{standard_predictor, Engine, SimBackend, CLOCK_EPS};
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn random_policy(rng: &mut Rng) -> Policy {
    match rng.range(0, 3) {
        0 => Policy::Vllm,
        1 => Policy::LayerKv { slo_aware: true },
        _ => Policy::LayerKv { slo_aware: false },
    }
}

/// Two-tier by default; sometimes starved-host, sometimes three-tier —
/// the shapes that park KV off-GPU and so exercise the stability gate.
fn random_cfg(rng: &mut Rng) -> ServingConfig {
    let mut cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
    if rng.chance(0.3) {
        cfg.cpu_swap_bytes = 1u64 << rng.range(28, 38);
    }
    if rng.chance(0.4) {
        cfg = cfg.with_disk(DiskSpec::nvme_4tb());
    }
    cfg
}

fn random_trace(rng: &mut Rng, n: usize) -> Trace {
    let rate = rng.f64() * 4.0 + 0.5;
    let arrivals = if rng.chance(0.4) {
        Arrivals::bursty(rate, rng.f64() * 2.0 + 1.5)
    } else {
        Arrivals::Poisson { rate }
    };
    if rng.chance(0.5) {
        let mut w = ShareGptWorkload::paper(rate, n);
        w.arrivals = arrivals;
        w.generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 256),
            n_requests: n,
            arrivals,
        }
        .generate(rng)
    }
}

/// Full machine-state comparison: clock bits, per-tier pool counts, queue
/// and running sizes, and every live table's tokens / per-tier layer and
/// block aggregates ("pool state" in the acceptance sense — block ids are
/// interchangeable by construction, counts and residency are semantics).
fn assert_same_machine_state(
    a: &Engine<SimBackend>,
    b: &Engine<SimBackend>,
    submitted: usize,
    what: &str,
) {
    assert_eq!(a.now().to_bits(), b.now().to_bits(), "{what}: clocks diverge");
    assert_eq!(
        (a.kv.gpu.used(), a.kv.cpu.used(), a.kv.disk.used()),
        (b.kv.gpu.used(), b.kv.cpu.used(), b.kv.disk.used()),
        "{what}: pool usage diverges"
    );
    assert_eq!(
        (a.kv.gpu.available(), a.kv.cpu.available(), a.kv.disk.available()),
        (b.kv.gpu.available(), b.kv.cpu.available(), b.kv.disk.available()),
        "{what}: pool availability diverges"
    );
    a.kv.gpu.check().unwrap();
    a.kv.cpu.check().unwrap();
    a.kv.disk.check().unwrap();
    assert_eq!(a.waiting_len(), b.waiting_len(), "{what}: queue depth diverges");
    assert_eq!(a.running_len(), b.running_len(), "{what}: running set diverges");
    for rid in 0..submitted {
        match (a.kv.table(rid), b.kv.table(rid)) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.tokens, y.tokens, "{what}: req {rid} token count");
                assert_eq!(
                    (x.n_gpu_layers(), x.n_cpu_layers(), x.n_disk_layers()),
                    (y.n_gpu_layers(), y.n_cpu_layers(), y.n_disk_layers()),
                    "{what}: req {rid} layer residency"
                );
                assert_eq!(
                    (x.gpu_blocks_held(), x.cpu_blocks_held(), x.disk_blocks_held()),
                    (y.gpu_blocks_held(), y.cpu_blocks_held(), y.disk_blocks_held()),
                    "{what}: req {rid} blocks held"
                );
                x.check().unwrap();
            }
            _ => panic!("{what}: req {rid} table presence diverges"),
        }
    }
}

/// End-to-end `try_run`: macro-stepping vs single-stepping on the same
/// trace must produce bit-identical records, makespan, stats (including
/// the dropped list and every f64 accumulator via `EngineStats`'s
/// `PartialEq`), and tier-transition logs — with drained pools on both
/// sides and never MORE scheduler invocations on the macro path.
#[test]
fn prop_macro_stepping_bit_identical_end_to_end() {
    prop(8, |rng| {
        let n = rng.range_usize(5, 30);
        let trace = random_trace(rng, n);
        let cfg = random_cfg(rng);
        let predictor = standard_predictor(&trace, 0.8);

        let mut fast = Engine::new(cfg.clone(), predictor.clone());
        fast.set_macro_steps(true);
        fast.enable_transition_log();
        let rep_fast = fast.run(&trace);

        let mut slow = Engine::new(cfg.clone(), predictor);
        slow.set_macro_steps(false);
        slow.enable_transition_log();
        let rep_slow = slow.run(&trace);

        let what = format!("{:?}", cfg.policy);
        assert_eq!(rep_fast.records, rep_slow.records, "{what}: records diverge");
        assert_eq!(
            rep_fast.makespan.to_bits(),
            rep_slow.makespan.to_bits(),
            "{what}: makespan diverges"
        );
        assert_eq!(fast.stats(), slow.stats(), "{what}: stats diverge");
        assert_eq!(
            fast.take_transitions(),
            slow.take_transitions(),
            "{what}: tier-transition logs diverge"
        );
        assert_eq!(
            (fast.kv.gpu.used(), fast.kv.cpu.used(), fast.kv.disk.used()),
            (0, 0, 0),
            "{what}: macro path leaked blocks"
        );
        assert_eq!(
            (slow.kv.gpu.used(), slow.kv.cpu.used(), slow.kv.disk.used()),
            (0, 0, 0)
        );
        assert!(
            fast.sched_invocations() <= slow.sched_invocations(),
            "{what}: macro path must never invoke the scheduler more often \
             ({} vs {})",
            fast.sched_invocations(),
            slow.sched_invocations()
        );
    });
}

/// The incremental drive (the cluster lockstep shape): both engines are
/// stepped to each arrival with the arrival as the fast-forward horizon,
/// and the WHOLE machine state — clock bits, pools, tables — must agree
/// at every submit boundary and after the drain.
#[test]
fn prop_macro_stepping_pool_state_matches_at_every_arrival() {
    prop(6, |rng| {
        let n = rng.range_usize(5, 25);
        let trace = random_trace(rng, n);
        let cfg = random_cfg(rng);
        let predictor = standard_predictor(&trace, 0.8);

        let mut fast = Engine::new(cfg.clone(), predictor.clone());
        fast.set_macro_steps(true);
        let mut slow = Engine::new(cfg.clone(), predictor.clone());
        slow.set_macro_steps(false);

        let mut submitted = 0usize;
        for tr in &trace.requests {
            for e in [&mut fast, &mut slow] {
                while tr.arrival > e.now() + CLOCK_EPS {
                    if !e.step_once_until(false, tr.arrival).unwrap() {
                        break;
                    }
                }
                if tr.arrival > e.now() + CLOCK_EPS {
                    e.wait_until(tr.arrival);
                }
                e.submit(tr, predictor.predict(tr.id, tr.output_len));
            }
            submitted += 1;
            assert_same_machine_state(
                &fast,
                &slow,
                submitted,
                &format!("{:?} after submit {}", cfg.policy, tr.id),
            );
        }
        for e in [&mut fast, &mut slow] {
            while e.has_work() {
                if !e.step_once(true).unwrap() {
                    break;
                }
            }
        }
        assert_same_machine_state(&fast, &slow, submitted, "after drain");
        let rep_fast = fast.take_report();
        let rep_slow = slow.take_report();
        assert_eq!(rep_fast.records, rep_slow.records);
        assert_eq!(rep_fast.makespan.to_bits(), rep_slow.makespan.to_bits());
        assert_eq!(fast.stats(), slow.stats());
    });
}

/// Cluster shapes: a macro-stepping fleet must reproduce the single-step
/// fleet exactly — merged records, routing counts, drops, and per-replica
/// stats — under every router and replica count.
#[test]
fn prop_cluster_macro_stepping_matches_single_step() {
    prop(6, |rng| {
        let n = rng.range_usize(8, 32);
        let k = rng.range_usize(1, 6);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let ccfg = ClusterConfig::homogeneous(&cfg, k, router);

        let mut fast = Cluster::new(&ccfg);
        fast.set_macro_steps(true);
        let out_fast = fast.run(&trace).expect("sim cluster never fails");

        let mut slow = Cluster::new(&ccfg);
        slow.set_macro_steps(false);
        let out_slow = slow.run(&trace).expect("sim cluster never fails");

        let what = format!("router {} x{k}", router.name());
        assert_eq!(out_fast.merged.records, out_slow.merged.records, "{what}");
        assert_eq!(
            out_fast.merged.makespan.to_bits(),
            out_slow.merged.makespan.to_bits(),
            "{what}"
        );
        assert_eq!(out_fast.dropped, out_slow.dropped, "{what}");
        for (i, (a, b)) in
            out_fast.per_replica.iter().zip(&out_slow.per_replica).enumerate()
        {
            assert_eq!(a.routed, b.routed, "{what}: replica {i} routing");
            assert_eq!(a.report.records, b.report.records, "{what}: replica {i}");
            assert_eq!(&a.stats, &b.stats, "{what}: replica {i} stats");
        }
    });
}

/// The O(1) router-view aggregates must agree with their from-scratch
/// scans after every engine step and submit — exactly for the three
/// integer views, to float rounding for the prefill-seconds sum.
#[test]
fn prop_router_views_match_scan_oracles() {
    prop(6, |rng| {
        let n = rng.range_usize(5, 25);
        let trace = random_trace(rng, n);
        let cfg = random_cfg(rng);
        let predictor = standard_predictor(&trace, 0.8);
        let mut e = Engine::new(cfg, predictor.clone());
        e.set_macro_steps(rng.chance(0.5));

        let check = |e: &Engine<SimBackend>, what: &str| {
            assert_eq!(e.waiting_tokens(), e.waiting_tokens_scan(), "{what}");
            assert_eq!(e.running_tokens(), e.running_tokens_scan(), "{what}");
            assert_eq!(
                e.running_remaining_tokens(),
                e.running_remaining_tokens_scan(),
                "{what}"
            );
            let (cached, scan) = (e.waiting_prefill_s(), e.waiting_prefill_s_scan());
            assert!(
                (cached - scan).abs() <= 1e-9 * scan.abs().max(1.0),
                "{what}: waiting_prefill_s cached {cached} vs scan {scan}"
            );
        };

        for tr in &trace.requests {
            while tr.arrival > e.now() + CLOCK_EPS {
                if !e.step_once_until(false, tr.arrival).unwrap() {
                    break;
                }
                check(&e, "mid-drive");
            }
            if tr.arrival > e.now() + CLOCK_EPS {
                e.wait_until(tr.arrival);
            }
            e.submit(tr, predictor.predict(tr.id, tr.output_len));
            check(&e, "after submit");
        }
        while e.has_work() {
            if !e.step_once(true).unwrap() {
                break;
            }
            check(&e, "draining");
        }
        // drained: every view at exactly zero
        assert_eq!(e.waiting_tokens(), 0);
        assert_eq!(e.running_tokens(), 0);
        assert_eq!(e.running_remaining_tokens(), 0);
        assert_eq!(e.waiting_prefill_s().to_bits(), 0.0f64.to_bits());
    });
}

/// The acceptance bar, pinned deterministically: on a long-decode trace
/// the macro path must cut scheduler invocations by ≥10x while staying
/// bit-identical. (The wall-clock side of the same claim lives in the
/// `engine/fastforward_*` hotpath bench series.)
#[test]
fn fastforward_cuts_scheduler_invocations_10x_on_long_decode() {
    let trace = FixedWorkload {
        prompt_len: 512,
        output_len: 1536,
        n_requests: 8,
        arrivals: Arrivals::Poisson { rate: 4.0 },
    }
    .generate(&mut Rng::new(11));
    for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        let predictor = standard_predictor(&trace, 0.8);

        let mut fast = Engine::new(cfg.clone(), predictor.clone());
        fast.set_macro_steps(true);
        let rep_fast = fast.run(&trace);

        let mut slow = Engine::new(cfg, predictor);
        slow.set_macro_steps(false);
        let rep_slow = slow.run(&trace);

        assert_eq!(rep_fast.records, rep_slow.records, "{policy:?}");
        assert_eq!(rep_fast.makespan.to_bits(), rep_slow.makespan.to_bits());
        assert_eq!(fast.stats(), slow.stats(), "{policy:?}");
        assert!(
            slow.sched_invocations() >= 10 * fast.sched_invocations(),
            "{policy:?}: expected ≥10x fewer scheduler invocations, got {} (macro) \
             vs {} (single-step)",
            fast.sched_invocations(),
            slow.sched_invocations()
        );
    }
}
