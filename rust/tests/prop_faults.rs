//! Fault-injection invariants (randomized, seeded, replayable via
//! LAYERKV_PROP_SEED / LAYERKV_PROP_CASES — see util::prop):
//!
//! * empty-plan identity — `Cluster::with_faults(FaultPlan::default())`
//!   is **bit-identical** to a cluster built without faults, under every
//!   router, with decode fast-forwarding both on and off. The fault layer
//!   must cost exactly nothing when nothing is injected.
//! * conservation under arbitrary plans — for generated fault schedules
//!   (crashes incl. permanent ones, stragglers, I/O bursts), completions
//!   + rejections + retry-exhausted failures partition the trace's id
//!   space: no request is lost or answered twice, no matter which
//!   replicas die when.
//! * same-seed determinism — the same (trace, plan) pair replays
//!   byte-identically: records, makespan bits, fault summary, and the
//!   rendered fault-event log.
//! * drain exports — `Engine::drain` exports every unfinished request
//!   exactly once with its original lengths, closes admission, and
//!   leaves completed records intact.
//! * disk-fence degraded mode — an engine whose disk tier always errors
//!   fences it after `DISK_FENCE_K` consecutive failures and still
//!   conserves the trace as a two-tier machine.

use layerkv::cluster::{Cluster, ClusterConfig, FaultPlan, RouterPolicy};
use layerkv::config::{DiskSpec, Policy, ServingConfig};
use layerkv::coordinator::{Engine, LengthPredictor, CLOCK_EPS, DISK_FENCE_K};
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn random_policy(rng: &mut Rng) -> Policy {
    match rng.range(0, 3) {
        0 => Policy::Vllm,
        1 => Policy::LayerKv { slo_aware: true },
        _ => Policy::LayerKv { slo_aware: false },
    }
}

fn random_trace(rng: &mut Rng, n: usize) -> Trace {
    let rate = rng.f64() * 4.0 + 0.5;
    let arrivals = if rng.chance(0.4) {
        Arrivals::bursty(rate, rng.f64() * 2.0 + 1.5)
    } else {
        Arrivals::Poisson { rate }
    };
    if rng.chance(0.5) {
        let mut w = ShareGptWorkload::paper(rate, n);
        w.arrivals = arrivals;
        w.generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals,
        }
        .generate(rng)
    }
}

/// The merged ids + drops + failures must be a permutation of `0..n`.
fn assert_conserved(out: &layerkv::cluster::ClusterReport, n: usize, label: &str) {
    assert_eq!(out.accounted(), n, "{label}: accounting mismatch");
    let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
    ids.extend(out.dropped.iter().copied());
    ids.extend(out.failed.iter().copied());
    ids.sort_unstable();
    assert_eq!(
        ids,
        (0..n).collect::<Vec<_>>(),
        "{label}: completions + drops + failures must partition the trace"
    );
}

#[test]
fn prop_empty_fault_plan_is_bit_identical_to_no_plan() {
    prop(6, |rng| {
        let n = rng.range_usize(6, 28);
        let k = rng.range_usize(1, 5);
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        for router in RouterPolicy::ALL {
            for macro_steps in [true, false] {
                let mut plain = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, *router));
                plain.set_macro_steps(macro_steps);
                let a = plain.run(&trace).expect("sim cluster never fails");
                let mut faulted = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, *router))
                    .with_faults(FaultPlan::default());
                faulted.set_macro_steps(macro_steps);
                let b = faulted.run(&trace).expect("sim cluster never fails");
                let label =
                    format!("router {} k={k} macro={macro_steps}", router.name());
                assert_eq!(a.merged.records, b.merged.records, "{label}: records");
                assert_eq!(
                    a.merged.makespan.to_bits(),
                    b.merged.makespan.to_bits(),
                    "{label}: makespan bits"
                );
                assert_eq!(a.dropped, b.dropped, "{label}: drops");
                assert!(b.failed.is_empty(), "{label}: empty plan can fail nothing");
                assert!(faulted.fault_log().is_empty(), "{label}: no events fire");
                for (pa, pb) in a.per_replica.iter().zip(&b.per_replica) {
                    assert_eq!(pa.routed, pb.routed, "{label}: routing diverged");
                    assert_eq!(&pa.stats, &pb.stats, "{label}: engine stats diverged");
                }
                let f = b.faults.expect("plan attached");
                assert_eq!(f.crashes + f.recoveries + f.straggler_windows + f.io_bursts, 0);
                assert_eq!(f.retries, 0);
                assert_eq!(f.downtime_s, 0.0);
            }
        }
    });
}

#[test]
fn prop_generated_fault_plans_preserve_conservation() {
    prop(8, |rng| {
        let n = rng.range_usize(10, 36);
        let k = rng.range_usize(2, 5);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let horizon = trace
            .requests
            .last()
            .map(|r| r.arrival)
            .unwrap_or(0.0)
            .max(1.0);
        // a horizon slightly past the last arrival also lands events in
        // the drain phase
        let plan = FaultPlan::generate(rng.range(0, 1 << 30) as u64, k, horizon * 1.3);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router)).with_faults(plan.clone());
        let out = cluster.run(&trace).expect("faulted sim cluster never errors");
        let label = format!("router {} k={k} plan={plan:?}", router.name());
        assert_conserved(&out, n, &label);
        let f = out.faults.as_ref().expect("plan attached");
        assert_eq!(f.failed, out.failed.len(), "{label}: summary/report failed mismatch");
        assert!(
            f.crashes >= f.recoveries,
            "{label}: cannot recover more often than crashing"
        );
        // every fired event came from the compiled schedule, in order
        let fired = cluster.fault_log();
        let schedule = plan.events();
        assert!(fired.len() <= schedule.len(), "{label}: phantom events");
        assert_eq!(
            fired,
            &schedule[..fired.len()],
            "{label}: events must fire in schedule order"
        );
    });
}

#[test]
fn prop_same_seed_fault_runs_are_byte_identical() {
    prop(6, |rng| {
        let n = rng.range_usize(8, 30);
        let k = rng.range_usize(2, 4);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let horizon = trace
            .requests
            .last()
            .map(|r| r.arrival)
            .unwrap_or(0.0)
            .max(1.0);
        let plan = FaultPlan::generate(rng.range(0, 1 << 30) as u64, k, horizon);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let run = |plan: FaultPlan| {
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router))
                .with_faults(plan);
            let out = cluster.run(&trace).expect("faulted sim cluster never errors");
            let log: Vec<String> =
                cluster.fault_log().iter().map(|e| e.render()).collect();
            (out, log)
        };
        let (a, log_a) = run(plan.clone());
        let (b, log_b) = run(plan);
        assert_eq!(a.merged.records, b.merged.records, "records must replay");
        assert_eq!(a.merged.makespan.to_bits(), b.merged.makespan.to_bits());
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.failed, b.failed);
        assert_eq!(a.faults, b.faults, "fault summary must replay");
        assert_eq!(log_a, log_b, "fault-event log must replay byte-identically");
    });
}

#[test]
fn prop_drain_exports_every_unfinished_request_exactly_once() {
    prop(8, |rng| {
        let n = rng.range_usize(5, 24);
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let mut engine = Engine::new(cfg, LengthPredictor::new(2, 0.8, 42));
        // submit a random prefix at its arrivals, advancing in between so
        // some requests complete, some run, some still queue
        let cut = rng.range_usize(1, n + 1);
        for tr in trace.requests.iter().take(cut) {
            while tr.arrival > engine.now() + CLOCK_EPS {
                if !engine.step_once_until(false, tr.arrival).expect("sim engine") {
                    break;
                }
            }
            if tr.arrival > engine.now() + CLOCK_EPS {
                engine.wait_until(tr.arrival);
            }
            engine.submit(tr, (tr.prompt_len, tr.output_len));
        }
        let completed_before = engine.records().len();
        let drained = engine.drain();
        assert!(!engine.has_work(), "drain leaves no queued or running work");
        assert!(!engine.admission_open(), "drain closes admission");
        assert_eq!(engine.records().len(), completed_before, "drain forges no records");
        // exported ids + completed ids + rejected ids partition the
        // submitted prefix, and exports carry their ORIGINAL lengths
        let mut ids: Vec<usize> = drained.iter().map(|d| d.id).collect();
        ids.extend(engine.records().iter().map(|r| r.id));
        ids.extend(engine.stats().dropped.iter().copied());
        ids.sort_unstable();
        assert_eq!(ids, (0..cut).collect::<Vec<_>>());
        for d in &drained {
            let tr = &trace.requests[d.id];
            assert_eq!(d.prompt_len, tr.prompt_len, "original prompt length");
            assert_eq!(d.output_len, tr.output_len, "original output length");
            assert_eq!(d.arrival, tr.arrival, "original arrival");
        }
        // a second drain has nothing left to export
        assert!(engine.drain().is_empty());
        // reopen: the engine serves again
        engine.reopen_admission();
        assert!(engine.admission_open());
    });
}

/// Deterministic: a disk tier that always errors is fenced after
/// `DISK_FENCE_K` consecutive failures, and the engine finishes the trace
/// as a two-tier + recompute machine with every request accounted.
#[test]
fn faulty_disk_tier_fences_and_degrades_to_two_tier() {
    let mut cfg = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    // starve the host pool so the disk tier sees real traffic
    cfg.cpu_swap_bytes = 1 << 30;
    cfg.node.disk = DiskSpec::nvme(64 * (1u64 << 30));
    let trace = FixedWorkload {
        prompt_len: 4096,
        output_len: 64,
        n_requests: 24,
        arrivals: Arrivals::Poisson { rate: 1.0 },
    }
    .generate(&mut Rng::new(23));
    let mut engine = Engine::new(cfg, LengthPredictor::new(2, 0.8, 42));
    engine.set_disk_faulty(true);
    let report = engine.try_run(&trace).expect("degraded engine still serves");
    let stats = engine.stats();
    assert!(
        stats.disk_io_errors >= DISK_FENCE_K as u64,
        "the starved-host workload must actually hit the disk tier \
         (got {} errors)",
        stats.disk_io_errors
    );
    assert!(engine.disk_fenced(), "K consecutive errors fence the tier");
    assert_eq!(
        report.records.len() + stats.dropped.len(),
        24,
        "degraded mode still conserves the trace"
    );
    // healthy control: same config and trace, no injected faults
    let mut cfg2 = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    cfg2.cpu_swap_bytes = 1 << 30;
    cfg2.node.disk = DiskSpec::nvme(64 * (1u64 << 30));
    let mut healthy = Engine::new(cfg2, LengthPredictor::new(2, 0.8, 42));
    let h = healthy.try_run(&trace).expect("sim engine");
    assert!(!healthy.disk_fenced());
    assert_eq!(healthy.stats().disk_io_errors, 0);
    assert_eq!(h.records.len() + healthy.stats().dropped.len(), 24);
}
