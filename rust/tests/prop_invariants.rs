//! Cross-module property tests (randomized, seeded, replayable via
//! LAYERKV_PROP_SEED / LAYERKV_PROP_CASES — see util::prop).

#[path = "support/reference_engine.rs"]
mod reference_engine;

use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::EngineStats;
use layerkv::coordinator::block::{KvManager, LayerBlockTable};
use layerkv::coordinator::engine::run_trace_oracle;
use layerkv::coordinator::predict::LengthPredictor;
use layerkv::coordinator::run_trace;
use layerkv::experiments::par_map_threads;
use layerkv::sim::{BusyWindow, CostModel, PcieLink};
use layerkv::util::prop::prop;
use layerkv::util::{Rng, Series};
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

#[test]
fn prop_engine_no_request_lost_any_policy_any_workload() {
    prop(12, |rng| {
        let policy = match rng.range(0, 3) {
            0 => Policy::Vllm,
            1 => Policy::LayerKv { slo_aware: true },
            _ => Policy::LayerKv { slo_aware: false },
        };
        let n = rng.range_usize(5, 40);
        let trace = if rng.chance(0.5) {
            ShareGptWorkload::paper(rng.f64() * 6.0 + 0.5, n).generate(rng)
        } else {
            FixedWorkload {
                prompt_len: rng.range_usize(16, 4096),
                output_len: rng.range_usize(4, 256),
                n_requests: n,
                arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.2 },
            }
            .generate(rng)
        };
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        let (rep, stats) = run_trace(cfg, &trace, 0.8);
        assert_eq!(rep.records.len() + stats.dropped.len(), n);
        // causality on every record
        for r in &rep.records {
            assert!(r.arrival <= r.prefill_start + 1e-9);
            assert!(r.prefill_start <= r.first_token);
            assert!(r.first_token <= r.finish);
        }
    });
}

/// The §Perf refactor's safety net: the incremental-state engine (cached
/// running aggregates, sorted running set, event-driven updates) must be
/// *bit-identical* to the recompute-from-scratch oracle on any trace,
/// under every policy.
#[test]
fn prop_incremental_engine_matches_recompute_oracle() {
    prop(8, |rng| {
        let n = rng.range_usize(5, 30);
        let trace: Trace = if rng.chance(0.5) {
            ShareGptWorkload::paper(rng.f64() * 5.0 + 0.5, n).generate(rng)
        } else {
            FixedWorkload {
                prompt_len: rng.range_usize(16, 4096),
                output_len: rng.range_usize(4, 128),
                n_requests: n,
                arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.2 },
            }
            .generate(rng)
        };
        for policy in [
            Policy::Vllm,
            Policy::LayerKv { slo_aware: true },
            Policy::LayerKv { slo_aware: false },
        ] {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let (inc, inc_stats) = run_trace(cfg.clone(), &trace, 0.8);
            let (ora, ora_stats) = run_trace_oracle(cfg, &trace, 0.8);
            assert_eq!(inc.records, ora.records, "{policy:?}: records diverge");
            assert_eq!(
                inc.makespan.to_bits(),
                ora.makespan.to_bits(),
                "{policy:?}: makespan diverges"
            );
            assert_eq!(
                (inc_stats.steps, inc_stats.prefill_steps, inc_stats.decode_steps),
                (ora_stats.steps, ora_stats.prefill_steps, ora_stats.decode_steps),
                "{policy:?}: step counts diverge"
            );
            assert_eq!(inc_stats.preemptions, ora_stats.preemptions);
            assert_eq!(inc_stats.dropped, ora_stats.dropped);
        }
    });
}

/// Bit-level stats equality: every counter identical, every f64
/// accumulator identical to the bit.
fn assert_stats_bit_identical(a: &EngineStats, b: &EngineStats, what: &str) {
    assert_eq!(
        (a.steps, a.prefill_steps, a.decode_steps, a.preemptions),
        (b.steps, b.prefill_steps, b.decode_steps, b.preemptions),
        "{what}: step counters diverge"
    );
    assert_eq!(
        (a.proactive_offload_layers, a.oom_forced_offload_layers, a.onloaded_layers),
        (b.proactive_offload_layers, b.oom_forced_offload_layers, b.onloaded_layers),
        "{what}: residency counters diverge"
    );
    assert_eq!(a.dropped, b.dropped, "{what}: dropped lists diverge");
    assert_eq!(
        a.offload_bytes.to_bits(),
        b.offload_bytes.to_bits(),
        "{what}: offload_bytes diverges"
    );
    assert_eq!(
        a.onload_stream_bytes.to_bits(),
        b.onload_stream_bytes.to_bits(),
        "{what}: onload_stream_bytes diverges"
    );
    assert_eq!(
        a.stream_stall_s.to_bits(),
        b.stream_stall_s.to_bits(),
        "{what}: stream_stall_s diverges"
    );
    assert_eq!(
        a.contention_s.to_bits(),
        b.contention_s.to_bits(),
        "{what}: contention_s diverges"
    );
}

/// The `ExecutionBackend` refactor's contract: `Engine<SimBackend>` must
/// reproduce the pre-refactor monolithic engine (preserved verbatim in
/// tests/support/reference_engine.rs) bit-for-bit — records, makespan,
/// and every stat — across randomized traces, under every policy, in
/// both incremental and recompute-oracle mode.
#[test]
fn prop_unified_engine_matches_pre_refactor_reference() {
    prop(8, |rng| {
        let n = rng.range_usize(5, 30);
        let trace: Trace = if rng.chance(0.5) {
            ShareGptWorkload::paper(rng.f64() * 5.0 + 0.5, n).generate(rng)
        } else {
            FixedWorkload {
                prompt_len: rng.range_usize(16, 4096),
                output_len: rng.range_usize(4, 128),
                n_requests: n,
                arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.2 },
            }
            .generate(rng)
        };
        for policy in [
            Policy::Vllm,
            Policy::LayerKv { slo_aware: true },
            Policy::LayerKv { slo_aware: false },
        ] {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let (new_rep, new_stats) = run_trace(cfg.clone(), &trace, 0.8);
            let (ref_rep, ref_stats) =
                reference_engine::run_trace_reference(cfg.clone(), &trace, 0.8);
            assert_eq!(new_rep.records, ref_rep.records, "{policy:?}: records diverge");
            assert_eq!(
                new_rep.makespan.to_bits(),
                ref_rep.makespan.to_bits(),
                "{policy:?}: makespan diverges"
            );
            assert_stats_bit_identical(&new_stats, &ref_stats, &format!("{policy:?}"));

            let (new_o, new_os) = run_trace_oracle(cfg.clone(), &trace, 0.8);
            let (ref_o, ref_os) =
                reference_engine::run_trace_reference_oracle(cfg, &trace, 0.8);
            assert_eq!(new_o.records, ref_o.records, "{policy:?}: oracle records diverge");
            assert_stats_bit_identical(&new_os, &ref_os, &format!("{policy:?} oracle"));
        }
    });
}

/// The parallel experiment harness must produce exactly the rows serial
/// execution produces — same values, same order — for any worker count.
#[test]
fn prop_parallel_harness_rows_match_serial() {
    let cells: Vec<(usize, u64)> =
        (0..6usize).map(|i| (128 + 256 * i, 100 + i as u64)).collect();
    let run_cell = |&(ctx, seed): &(usize, u64)| {
        let cfg =
            ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true });
        let trace = FixedWorkload {
            prompt_len: ctx,
            output_len: 32,
            n_requests: 8,
            arrivals: Arrivals::Poisson { rate: 2.0 },
        }
        .generate(&mut Rng::new(seed));
        let (rep, stats) = run_trace(cfg, &trace, 0.8);
        (
            rep.ttft().mean().to_bits(),
            rep.makespan.to_bits(),
            rep.records.len(),
            stats.steps,
        )
    };
    let serial = par_map_threads(&cells, 1, run_cell);
    for threads in [2usize, 4, 8] {
        let par = par_map_threads(&cells, threads, run_cell);
        assert_eq!(par, serial, "threads={threads}");
    }
}

#[test]
fn prop_interleaved_retained_is_well_formed() {
    prop(500, |rng| {
        let l = rng.range_usize(1, 96);
        let x = rng.range_usize(0, l + 1);
        let r = LayerBlockTable::interleaved_retained(l, x);
        assert_eq!(r.len(), x, "l={l} x={x}");
        // sorted, unique, in range
        assert!(r.windows(2).all(|w| w[0] < w[1]), "l={l} x={x} r={r:?}");
        assert!(r.iter().all(|&i| i < l));
    });
}

/// Block conservation across ALL tiers, checked after EVERY step of a
/// randomized op mix: each tier's pool accounting equals the sum over
/// live tables, held + free equals each tier's capacity, the free lists
/// stay well-formed, and every table's cached per-tier aggregates match a
/// recount (`LayerBlockTable::check`). Half the cases run the two-tier
/// configuration (disk capacity 0) and additionally assert the disk tier
/// is never touched.
#[test]
fn prop_kv_manager_conservation_with_policy_mix() {
    prop(60, |rng| {
        let n_layers = rng.range_usize(1, 48);
        let gpu = rng.range_usize(n_layers, 4000);
        let cpu = rng.range_usize(n_layers, 4000);
        let disk = if rng.chance(0.5) { 0 } else { rng.range_usize(n_layers, 4000) };
        let mut m = KvManager::new_tiered(gpu, cpu, disk, 16, n_layers);
        let mut live = Vec::new();
        let check_all = |m: &KvManager, live: &[usize]| {
            let gpu_held: usize =
                live.iter().map(|&r| m.table(r).unwrap().gpu_blocks_held()).sum();
            let cpu_held: usize =
                live.iter().map(|&r| m.table(r).unwrap().cpu_blocks_held()).sum();
            let disk_held: usize =
                live.iter().map(|&r| m.table(r).unwrap().disk_blocks_held()).sum();
            assert_eq!(m.gpu.used(), gpu_held);
            assert_eq!(m.cpu.used(), cpu_held);
            assert_eq!(m.disk.used(), disk_held);
            assert_eq!(m.gpu.available() + gpu_held, m.gpu.total());
            assert_eq!(m.cpu.available() + cpu_held, m.cpu.total());
            assert_eq!(m.disk.available() + disk_held, m.disk.total());
            m.gpu.check().unwrap();
            m.cpu.check().unwrap();
            m.disk.check().unwrap();
            for &r in live {
                m.table(r).unwrap().check().unwrap();
            }
            if m.disk.total() == 0 {
                assert_eq!(disk_held, 0, "two-tier config must never touch disk");
            }
        };
        for id in 0..rng.range_usize(1, 40) {
            let tokens = rng.range_usize(1, 512);
            let x = rng.range_usize(0, n_layers + 1);
            if m.allocate_layerwise(id, tokens, x).is_ok() {
                live.push(id);
            }
            check_all(&m, &live);
        }
        for _ in 0..rng.range_usize(0, 200) {
            if live.is_empty() {
                break;
            }
            let id = live[rng.range_usize(0, live.len())];
            match rng.range(0, 6) {
                0 => {
                    let _ = m.append_token(id);
                }
                1 => {
                    let _ = m.offload_layer(id, rng.range_usize(0, n_layers));
                }
                2 => {
                    let _ = m.onload_layer(id, rng.range_usize(0, n_layers));
                }
                3 => {
                    let _ = m.spill_layer(id, rng.range_usize(0, n_layers));
                }
                4 => {
                    let _ = m.unspill_layer(id, rng.range_usize(0, n_layers));
                }
                _ => {
                    let _ = m.promote_disk_layer(id, rng.range_usize(0, n_layers));
                }
            }
            check_all(&m, &live);
        }
        for id in live {
            m.release(id).unwrap();
        }
        assert_eq!(m.gpu.used(), 0);
        assert_eq!(m.cpu.used(), 0);
        assert_eq!(m.disk.used(), 0);
    });
}

/// The tentpole's headline guarantee, property-tested: with the disk tier
/// DISABLED (capacity 0 — the default on every preset), the tiered engine
/// is bit-identical to the pre-tentpole reference engine on randomized
/// traces under every policy — and all disk-side stats stay exactly zero.
#[test]
fn prop_two_tier_config_bit_identical_to_reference() {
    prop(8, |rng| {
        let n = rng.range_usize(5, 30);
        let trace: Trace = if rng.chance(0.5) {
            ShareGptWorkload::paper(rng.f64() * 5.0 + 0.5, n).generate(rng)
        } else {
            FixedWorkload {
                prompt_len: rng.range_usize(16, 4096),
                output_len: rng.range_usize(4, 128),
                n_requests: n,
                arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.2 },
            }
            .generate(rng)
        };
        for policy in [
            Policy::Vllm,
            Policy::LayerKv { slo_aware: true },
            Policy::LayerKv { slo_aware: false },
        ] {
            // vary the host pool too: host pressure without a disk tier
            // must degrade exactly like the pre-tentpole engine
            let mut cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            if rng.chance(0.3) {
                cfg.cpu_swap_bytes = 1u64 << rng.range(28, 38);
            }
            let (new_rep, new_stats) = run_trace(cfg.clone(), &trace, 0.8);
            let (ref_rep, ref_stats) =
                reference_engine::run_trace_reference(cfg, &trace, 0.8);
            assert_eq!(new_rep.records, ref_rep.records, "{policy:?}: records diverge");
            assert_eq!(new_rep.makespan.to_bits(), ref_rep.makespan.to_bits());
            assert_stats_bit_identical(&new_stats, &ref_stats, &format!("{policy:?}"));
            assert_eq!(new_stats.spilled_layers, 0);
            assert_eq!(new_stats.disk_promoted_layers, 0);
            assert_eq!(new_stats.spill_bytes.to_bits(), 0.0f64.to_bits());
            assert_eq!(new_stats.disk_stall_s.to_bits(), 0.0f64.to_bits());
        }
    });
}

/// Adding a disk tier must be a no-op while the host pool stays ample:
/// same reports, same stats, zero spill traffic — the hierarchy only
/// engages under host pressure.
#[test]
fn prop_ample_host_disk_tier_is_inert() {
    use layerkv::config::DiskSpec;
    prop(6, |rng| {
        let n = rng.range_usize(5, 25);
        let trace: Trace = FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.2 },
        }
        .generate(rng);
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            // default 256 GB host swap: ample for these traces
            let base = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let tiered = base.clone().with_disk(DiskSpec::nvme_4tb());
            let (a, sa) = run_trace(base, &trace, 0.8);
            let (b, sb) = run_trace(tiered, &trace, 0.8);
            assert_eq!(a.records, b.records, "{policy:?}: disk tier changed behaviour");
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_stats_bit_identical(&sa, &sb, &format!("{policy:?} ample-host"));
            assert_eq!(sb.spilled_layers, 0);
            assert_eq!(sb.spill_bytes.to_bits(), 0.0f64.to_bits());
        }
    });
}

/// Under host-saturating load the hierarchy must stay conservative: the
/// engine's pools drain to zero after the run, every request is accounted
/// for (completed or rejected), and spill traffic only appears when the
/// disk tier exists.
#[test]
fn prop_tiered_engine_conserves_and_completes() {
    use layerkv::config::DiskSpec;
    prop(6, |rng| {
        let n = rng.range_usize(4, 16);
        let trace: Trace = FixedWorkload {
            prompt_len: rng.range_usize(2048, 8192),
            output_len: rng.range_usize(4, 64),
            n_requests: n,
            arrivals: Arrivals::Poisson { rate: rng.f64() * 2.0 + 0.5 },
        }
        .generate(rng);
        let mut cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_disk(DiskSpec::nvme_4tb());
        // starve the host pool so spills actually engage
        cfg.cpu_swap_bytes = 1u64 << rng.range(28, 31);
        let predictor = LengthPredictor::new(
            trace.requests.iter().map(|r| r.output_len).max().unwrap_or(64).max(2),
            0.8,
            42,
        );
        let mut e = layerkv::coordinator::Engine::new(cfg, predictor);
        e.enable_transition_log();
        let rep = e.run(&trace);
        let stats = e.stats().clone();
        let log = e.take_transitions();
        assert_eq!(rep.records.len() + stats.dropped.len(), n);
        assert_eq!(e.kv.gpu.used(), 0, "GPU pool must drain");
        assert_eq!(e.kv.cpu.used(), 0, "host pool must drain");
        assert_eq!(e.kv.disk.used(), 0, "disk pool must drain");
        // transition log consistency: every logged move names a valid tier
        // and the per-kind counts match the engine's counters
        let count = |from: u8, to: u8| {
            log.iter().filter(|t| t.from == from && t.to == to).count() as u64
        };
        assert_eq!(
            count(0, 1),
            stats.proactive_offload_layers + stats.oom_forced_offload_layers
        );
        assert_eq!(count(1, 0), stats.onloaded_layers);
        assert_eq!(count(1, 2), stats.spilled_layers);
        assert_eq!(count(2, 0), stats.disk_promoted_layers);
        assert!(log.iter().all(|t| t.from <= 2 && t.to <= 2 && t.from != t.to));
        assert!(log.windows(2).all(|w| w[0].t <= w[1].t), "log must be time-ordered");
    });
}

#[test]
fn prop_x_solve_always_hides_offload() {
    // For any model/seqlen, the solved x satisfies Eq. 3 >= Eq. 4.
    prop(200, |rng| {
        let mut cfg = match rng.range(0, 3) {
            0 => ServingConfig::llama2_7b_tp1(),
            1 => ServingConfig::yi_34b_tp2(),
            _ => ServingConfig::llama31_70b_tp4(),
        };
        // vary the link to hit x>0 regimes too
        cfg.node.pcie.bandwidth = [1.0e9, 5.0e9, 26.0e9][rng.range_usize(0, 3)];
        let m = CostModel::new(cfg.clone());
        let s = rng.range_usize(1, 16384);
        let x = m.min_resident_layers(s);
        assert!(x <= cfg.model.n_layers);
        let offloadable = cfg.model.n_layers - x;
        if offloadable > 0 {
            assert!(
                m.offload_time(s, offloadable)
                    <= m.prefill_compute_time(s) + m.offload_time(s, 1) + 1e-9,
                "s={s} x={x}: offload doesn't hide"
            );
        }
    });
}

#[test]
fn prop_pcie_chunking_never_increases_contention() {
    prop(200, |rng| {
        let bw = 5.0e9 + rng.f64() * 25.0e9;
        let n_win = rng.range_usize(0, 30);
        let mut t = rng.f64();
        let mut busy = Vec::new();
        for _ in 0..n_win {
            let start = t + rng.f64() * 0.05;
            let end = start + 1e-4 + rng.f64() * 0.05;
            busy.push(BusyWindow { start, end });
            t = end;
        }
        let bytes = rng.f64() * 2.0e9;
        let chunked = PcieLink::new(bw, 10e-6, true).schedule_swap(0.0, bytes, &busy);
        let naive = PcieLink::new(bw, 10e-6, false).schedule_swap(0.0, bytes, &busy);
        assert!(
            chunked.contended <= naive.contended + 1e-9,
            "chunking increased contention: {} vs {}",
            chunked.contended,
            naive.contended
        );
        // and chunking can only delay (never accelerate) the swap itself
        assert!(chunked.finish + 1e-9 >= naive.finish - 10e-6);
    });
}

#[test]
fn prop_predictor_bounds_are_consistent() {
    prop(300, |rng| {
        let max_len = rng.range_usize(8, 4096);
        let acc = rng.f64();
        let p = LengthPredictor::new(max_len, acc, rng.next_u64());
        let len = rng.range_usize(1, max_len);
        let (lo, hi) = p.predict(rng.range_usize(0, 1000), len);
        assert!(lo < hi, "empty bucket [{lo},{hi})");
        assert!(hi <= max_len.max(2));
    });
}

#[test]
fn prop_series_percentiles_are_monotone() {
    prop(200, |rng| {
        let mut s = Series::new();
        for _ in 0..rng.range_usize(1, 500) {
            s.push(rng.f64() * 1000.0);
        }
        let (p10, p50, p90, p99) =
            (s.percentile(10.0), s.percentile(50.0), s.percentile(90.0), s.percentile(99.0));
        assert!(p10 <= p50 && p50 <= p90 && p90 <= p99);
        assert!(s.min() <= p10 && p99 <= s.max() + 1e-12);
    });
}

#[test]
fn prop_traces_valid_for_any_seed() {
    prop(100, |rng: &mut Rng| {
        let n = rng.range_usize(1, 200);
        let t = ShareGptWorkload::paper(rng.f64() * 8.0 + 0.1, n).generate(rng);
        t.validate().unwrap();
        let f = FixedWorkload {
            prompt_len: rng.range_usize(1, 10000),
            output_len: rng.range_usize(1, 1000),
            n_requests: n,
            arrivals: Arrivals::Uniform { rate: rng.f64() * 5.0 + 0.1 },
        }
        .generate(rng);
        f.validate().unwrap();
    });
}
