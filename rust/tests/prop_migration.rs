//! Stateful-failover invariants: checkpointing, adoption, and live
//! migration (randomized, seeded, replayable via LAYERKV_PROP_SEED /
//! LAYERKV_PROP_CASES — see util::prop):
//!
//! * ckpt-off drive invariance — with checkpointing disabled, a faulted
//!   cluster run is **bit-identical** across the event-heap and lockstep
//!   drives, with decode fast-forwarding both on and off, under every
//!   generated fault plan. The failover/adoption machinery must cost
//!   exactly nothing when it is gated off.
//! * checkpointing is execution-invisible — enabling `--ckpt K` changes
//!   counters only: records, makespan bits, and drops are bit-identical
//!   to the same run without checkpointing (the write rides the idle
//!   disk link and never advances the clock).
//! * conservation + replay with checkpointing on — generated fault plans
//!   over a checkpoint-enabled fleet still partition the trace id space,
//!   and the same (trace, plan) pair replays byte-identically including
//!   the failover summary and fault-event log.
//! * planned migration — a `migrate=S>D@T` clause drains the source and
//!   adopts everything on the destination: nothing fails, nothing is
//!   charged to the retry budget, and the event joins the fault log.
//! * adopted decode is token-exact — a real (RefModel) engine drained
//!   mid-decode and adopted by a fresh engine emits bit-identical token
//!   streams to an uninterrupted run (`tests/golden/cluster_faulted.jsonl`
//!   covers the cluster-level replay of a faulted run).

use std::rc::Rc;

use layerkv::cluster::{
    Cluster, ClusterConfig, CrashWindow, FaultPlan, Migration, RouterPolicy,
};
use layerkv::config::{DiskSpec, Policy, ServingConfig};
use layerkv::coordinator::{Engine, KvManager, LengthPredictor};
use layerkv::runtime::{tiny_serving_config, PjrtBackend, RefModel, ServeRequest, TokenModel};
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::{trace, Trace, TraceRequest};

fn random_policy(rng: &mut Rng) -> Policy {
    match rng.range(0, 3) {
        0 => Policy::Vllm,
        1 => Policy::LayerKv { slo_aware: true },
        _ => Policy::LayerKv { slo_aware: false },
    }
}

fn random_trace(rng: &mut Rng, n: usize) -> Trace {
    let rate = rng.f64() * 4.0 + 0.5;
    let arrivals = if rng.chance(0.4) {
        Arrivals::bursty(rate, rng.f64() * 2.0 + 1.5)
    } else {
        Arrivals::Poisson { rate }
    };
    if rng.chance(0.5) {
        let mut w = ShareGptWorkload::paper(rate, n);
        w.arrivals = arrivals;
        w.generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals,
        }
        .generate(rng)
    }
}

fn horizon_of(trace: &Trace) -> f64 {
    trace.requests.last().map(|r| r.arrival).unwrap_or(0.0).max(1.0)
}

/// A checkpoint-capable fleet config: the sim presets default to no disk
/// tier, and checkpoints need somewhere durable to land.
fn ckpt_cfg(policy: Policy, every: usize) -> ServingConfig {
    let cfg = ServingConfig::llama2_7b_tp1()
        .with_policy(policy)
        .with_disk(DiskSpec::nvme_4tb());
    if every > 0 {
        cfg.with_checkpointing(every)
    } else {
        cfg
    }
}

/// The merged ids + drops + failures must be a permutation of `0..n`.
fn assert_conserved(out: &layerkv::cluster::ClusterReport, n: usize, label: &str) {
    assert_eq!(out.accounted(), n, "{label}: accounting mismatch");
    let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
    ids.extend(out.dropped.iter().copied());
    ids.extend(out.failed.iter().copied());
    ids.sort_unstable();
    assert_eq!(
        ids,
        (0..n).collect::<Vec<_>>(),
        "{label}: completions + drops + failures must partition the trace"
    );
}

type FaultedOutcome = (layerkv::cluster::ClusterReport, Vec<String>);

fn run_faulted(
    cfg: &ServingConfig,
    k: usize,
    router: RouterPolicy,
    plan: &FaultPlan,
    trace: &Trace,
    lockstep: bool,
    macro_steps: bool,
) -> FaultedOutcome {
    let mut cluster =
        Cluster::new(&ClusterConfig::homogeneous(cfg, k, router)).with_faults(plan.clone());
    cluster.set_lockstep(lockstep);
    cluster.set_macro_steps(macro_steps);
    let out = cluster.run(trace).expect("faulted sim cluster never errors");
    let log: Vec<String> = cluster.fault_log().iter().map(|e| e.render()).collect();
    (out, log)
}

/// With checkpointing off (the PR-6 fault plane), the new snapshot/adopt
/// machinery must be invisible: heap vs lockstep x macro on/off stay
/// bit-identical under generated fault plans, per router.
#[test]
fn prop_ckpt_off_faulted_runs_are_drive_invariant() {
    prop(4, |rng| {
        let n = rng.range_usize(8, 26);
        let k = rng.range_usize(2, 4);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let plan =
            FaultPlan::generate(rng.range(0, 1 << 30) as u64, k, horizon_of(&trace) * 1.2);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let (base, log_base) = run_faulted(&cfg, k, router, &plan, &trace, false, true);
        for (lockstep, macro_steps) in [(false, false), (true, true), (true, false)] {
            let (out, log) = run_faulted(&cfg, k, router, &plan, &trace, lockstep, macro_steps);
            let label = format!(
                "router {} k={k} lockstep={lockstep} macro={macro_steps}",
                router.name()
            );
            assert_eq!(base.merged.records, out.merged.records, "{label}: records");
            assert_eq!(
                base.merged.makespan.to_bits(),
                out.merged.makespan.to_bits(),
                "{label}: makespan bits"
            );
            assert_eq!(base.dropped, out.dropped, "{label}: drops");
            assert_eq!(base.failed, out.failed, "{label}: failures");
            assert_eq!(base.faults, out.faults, "{label}: fault summary");
            assert_eq!(log_base, log, "{label}: fault-event log");
        }
        assert_conserved(&base, n, "ckpt-off drive invariance");
        let f = base.faults.as_ref().expect("plan attached");
        assert_eq!(f.adoptions, 0, "no checkpoints -> every failover is a resubmit");
        assert_eq!(f.resumed_tokens, 0, "nothing durable to resume from");
    });
}

/// Checkpoint writes ride the idle disk link and advance no clock:
/// enabling them must not change execution, only the counters. (The
/// counters themselves are chunking-dependent across drive modes and are
/// deliberately NOT compared here.)
#[test]
fn prop_checkpointing_is_execution_invisible() {
    prop(5, |rng| {
        let n = rng.range_usize(6, 24);
        let k = rng.range_usize(1, 4);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let policy = random_policy(rng);
        let every = rng.range_usize(1, 32);
        for macro_steps in [true, false] {
            let mut off = Cluster::new(&ClusterConfig::homogeneous(
                &ckpt_cfg(policy, 0),
                k,
                router,
            ));
            off.set_macro_steps(macro_steps);
            let a = off.run(&trace).expect("sim cluster never fails");
            let mut on = Cluster::new(&ClusterConfig::homogeneous(
                &ckpt_cfg(policy, every),
                k,
                router,
            ));
            on.set_macro_steps(macro_steps);
            let b = on.run(&trace).expect("sim cluster never fails");
            let label =
                format!("router {} k={k} every={every} macro={macro_steps}", router.name());
            assert_eq!(a.merged.records, b.merged.records, "{label}: records");
            assert_eq!(
                a.merged.makespan.to_bits(),
                b.merged.makespan.to_bits(),
                "{label}: makespan bits"
            );
            assert_eq!(a.dropped, b.dropped, "{label}: drops");
            let off_writes: u64 = a.per_replica.iter().map(|p| p.stats.ckpt_writes).sum();
            assert_eq!(off_writes, 0, "{label}: checkpointing off writes nothing");
            if !b.merged.records.is_empty() {
                let on_writes: u64 = b.per_replica.iter().map(|p| p.stats.ckpt_writes).sum();
                assert!(
                    on_writes > 0,
                    "{label}: committed tokens with ckpt on must checkpoint"
                );
            }
        }
    });
}

/// Generated fault plans over a checkpoint-enabled fleet: the id space
/// still partitions, and the same (trace, plan) pair replays
/// byte-identically — including the adoption/recompute accounting.
#[test]
fn prop_checkpointed_faulted_runs_conserve_and_replay() {
    prop(6, |rng| {
        let n = rng.range_usize(10, 32);
        let k = rng.range_usize(2, 5);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let plan =
            FaultPlan::generate(rng.range(0, 1 << 30) as u64, k, horizon_of(&trace) * 1.3);
        let cfg = ckpt_cfg(random_policy(rng), rng.range_usize(1, 16));
        let (a, log_a) = run_faulted(&cfg, k, router, &plan, &trace, false, true);
        let (b, log_b) = run_faulted(&cfg, k, router, &plan, &trace, false, true);
        let label = format!("router {} k={k}", router.name());
        assert_conserved(&a, n, &label);
        assert_eq!(a.merged.records, b.merged.records, "{label}: records must replay");
        assert_eq!(a.merged.makespan.to_bits(), b.merged.makespan.to_bits(), "{label}");
        assert_eq!(a.failed, b.failed, "{label}: failures must replay");
        assert_eq!(a.faults, b.faults, "{label}: failover summary must replay");
        assert_eq!(log_a, log_b, "{label}: fault-event log must replay");
        let f = a.faults.as_ref().expect("plan attached");
        assert_eq!(f.failed, a.failed.len(), "{label}: summary/report failed mismatch");
        assert!(
            f.resumed_tokens == 0 || f.adoptions > 0,
            "{label}: resumed tokens imply adoptions"
        );
    });
}

/// A planned live migration moves every in-flight request to the
/// destination: nothing fails, the retry budget is untouched, and the
/// migration is visible in both the fault log and the summary.
#[test]
fn prop_planned_migration_moves_state_without_failures() {
    prop(6, |rng| {
        let n = rng.range_usize(8, 26);
        let k = 3usize;
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let trace = random_trace(rng, n);
        let src = rng.range_usize(0, k);
        let mut dst = rng.range_usize(0, k - 1);
        if dst >= src {
            dst += 1;
        }
        // strictly before the last arrival: events scheduled past the end
        // of the run legitimately never fire, and this one must
        let last = trace.requests.last().map(|r| r.arrival).unwrap_or(0.0);
        let plan = FaultPlan {
            migrations: vec![Migration { src, dst, at: last * 0.5 }],
            ..FaultPlan::default()
        };
        plan.validate().expect("hand-built migration plan is valid");
        let with_ckpt = rng.chance(0.5);
        let cfg = if with_ckpt {
            ckpt_cfg(random_policy(rng), 8)
        } else {
            ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng))
        };
        let (out, log) = run_faulted(&cfg, k, router, &plan, &trace, false, true);
        let label = format!("router {} {src}->{dst} ckpt={with_ckpt}", router.name());
        assert_conserved(&out, n, &label);
        assert!(out.failed.is_empty(), "{label}: migration never fails a request");
        let f = out.faults.as_ref().expect("plan attached");
        assert_eq!(f.migrations, 1, "{label}: the planned migration fires once");
        assert_eq!(f.retries, 0, "{label}: adoption is never charged as a retry");
        assert_eq!(log.len(), 1, "{label}: exactly the migration event fires");
        // same plan, same trace: byte-identical replay
        let (out2, log2) = run_faulted(&cfg, k, router, &plan, &trace, false, true);
        assert_eq!(out.merged.records, out2.merged.records, "{label}: replay");
        assert_eq!(out.faults, out2.faults, "{label}: summary replay");
        assert_eq!(log, log2, "{label}: log replay");
    });
}

// ---------------------------------------------------------------------
// Token-exact adoption on a real (RefModel) engine
// ---------------------------------------------------------------------

fn ref_jobs() -> Vec<ServeRequest> {
    (0..4)
        .map(|id| ServeRequest {
            id,
            prompt: (0..24 + id * 3).map(|t| ((id * 13 + t * 7) % 256) as i32).collect(),
            max_new_tokens: 8,
            arrival_s: 0.0,
        })
        .collect()
}

fn ref_trace(jobs: &[ServeRequest]) -> Trace {
    Trace {
        requests: jobs
            .iter()
            .map(|j| TraceRequest {
                id: j.id,
                arrival: 0.0,
                prompt_len: j.prompt.len(),
                output_len: j.max_new_tokens,
                prefix: Default::default(),
            })
            .collect(),
    }
}

/// A standalone `Engine` over the deterministic RefModel executor — the
/// same construction `RealEngine::serve` performs, minus the wrapper.
fn ref_engine(jobs: &[ServeRequest]) -> Engine<PjrtBackend<RefModel>> {
    let model = Rc::new(RefModel::new());
    let spec = model.spec().clone();
    let scfg = tiny_serving_config(&spec, Policy::LayerKv { slo_aware: true }, 8);
    let layer_block_bytes = scfg.block_size * 2 * spec.n_kv_heads * spec.head_dim * 4;
    let kv = KvManager::new_tiered(
        (2 << 20) / layer_block_bytes,
        4096,
        0,
        scfg.block_size,
        spec.n_layers,
    );
    let mut backend = PjrtBackend::new(model, 8);
    backend.load_jobs(jobs);
    let predictor = LengthPredictor::new(spec.max_seq.max(2), 1.0, 42);
    Engine::with_parts(scfg, kv, backend, predictor)
}

/// The tentpole's correctness anchor: interrupt a real engine mid-decode,
/// export snapshots, adopt them on a fresh engine (which has never seen
/// the prompts), and the completed token streams are bit-identical to an
/// uninterrupted run. The RefModel backend cannot restore KV, so this
/// exercises the degraded recompute-re-prefill adoption path end to end.
#[test]
fn adopted_requests_emit_bit_identical_tokens() {
    let jobs = ref_jobs();
    let trace = ref_trace(&jobs);

    // uninterrupted baseline
    let mut golden = ref_engine(&jobs);
    let report = golden.try_run(&trace).expect("ref engine serves");
    assert_eq!(report.records.len(), jobs.len(), "baseline completes everything");
    let base: Vec<(Vec<i32>, Vec<i32>)> = (0..jobs.len())
        .map(|rid| golden.backend.snapshot_tokens(rid).expect("baseline lane"))
        .collect();
    for (j, (_, out)) in jobs.iter().zip(&base) {
        assert_eq!(out.len(), j.max_new_tokens, "baseline emits full streams");
    }

    // interrupted run: submit everything, step a few scheduler rounds,
    // then drain with state mid-decode
    let mut victim = ref_engine(&jobs);
    let mirror = LengthPredictor::new(RefModel::new().spec().max_seq.max(2), 1.0, 42);
    for tr in &trace.requests {
        victim.submit(tr, mirror.predict(tr.id, tr.output_len));
    }
    for _ in 0..6 {
        victim.step_once(false).expect("victim step");
    }
    let snaps = victim.drain_with_state();
    assert_eq!(snaps.len(), jobs.len(), "nothing finished in 6 steps");
    assert!(
        snaps.iter().any(|s| s.generated > 0 && s.generated < s.output_len),
        "fixture must interrupt at least one request mid-decode"
    );
    for s in &snaps {
        let (prompt, out) = s.tokens.as_ref().expect("real backend exports tokens");
        assert_eq!(prompt, &jobs[s.id].prompt, "snapshot carries the prompt");
        assert_eq!(out.len(), s.generated, "snapshot tokens match progress");
    }

    // a fresh engine that never saw the jobs adopts every snapshot
    let mut survivor = ref_engine(&[]);
    for snap in &snaps {
        let (_, resumed) = survivor.adopt(snap);
        assert_eq!(resumed, 0, "RefModel cannot restore KV: recompute adoption");
    }
    while survivor.has_work() {
        survivor.step_once(true).expect("survivor step");
    }
    assert_eq!(survivor.records().len(), snaps.len(), "survivor finishes all adoptees");

    // adoption order is the survivor's dense local id order
    for (local, snap) in snaps.iter().enumerate() {
        let (prompt, out) = survivor.backend.snapshot_tokens(local).expect("adopted lane");
        let (gp, go) = &base[snap.id];
        assert_eq!(&prompt, gp, "request {}: prompt survives adoption", snap.id);
        assert_eq!(&out, go, "request {}: tokens must be bit-identical", snap.id);
    }
}

// ---------------------------------------------------------------------
// Golden faulted-cluster replay
// ---------------------------------------------------------------------

fn golden_faulted_trace() -> Trace {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/cluster_faulted.jsonl");
    trace::load(&path).expect("committed golden faulted trace must load")
}

/// The committed fault schedule replayed over the committed trace: one
/// transient crash, one permanent crash, a straggler window, and an I/O
/// burst, all mid-trace.
fn golden_fault_plan() -> FaultPlan {
    FaultPlan {
        crashes: vec![
            CrashWindow { replica: 1, at: 6.0, recover_at: 14.0 },
            CrashWindow { replica: 2, at: 18.0, recover_at: f64::INFINITY },
        ],
        stragglers: vec![layerkv::cluster::Straggler {
            replica: 0,
            from: 10.0,
            until: 16.0,
            slowdown: 2.5,
        }],
        io_bursts: vec![layerkv::cluster::IoBurst { replica: 0, from: 20.0, until: 24.0 }],
        ..FaultPlan::default()
    }
}

/// Golden replay (satellite 5): the committed faulted run — hand-written
/// trace, fixed plan, checkpointing off — is bit-identical between the
/// event-heap fast path and the lockstep oracle, macro-stepping on and
/// off, and replays deterministically.
#[test]
fn golden_faulted_cluster_replays_bit_identically() {
    let tr = golden_faulted_trace();
    assert_eq!(tr.requests.len(), 32, "committed fixture changed shape");
    let plan = golden_fault_plan();
    plan.validate().expect("committed fault plan is valid");
    let cfg = ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true });
    for router in RouterPolicy::ALL {
        let (fast, log_fast) = run_faulted(&cfg, 3, *router, &plan, &tr, false, true);
        assert_conserved(&fast, 32, router.name());
        let f = fast.faults.as_ref().expect("plan attached");
        assert_eq!(f.crashes, 2, "both committed crashes fire");
        assert_eq!(f.recoveries, 1, "only the transient crash recovers");
        for (lockstep, macro_steps) in [(true, true), (true, false), (false, false)] {
            let (out, log) = run_faulted(&cfg, 3, *router, &plan, &tr, lockstep, macro_steps);
            let label = format!(
                "router {} lockstep={lockstep} macro={macro_steps}",
                router.name()
            );
            assert_eq!(fast.merged.records, out.merged.records, "{label}: records");
            assert_eq!(
                fast.merged.makespan.to_bits(),
                out.merged.makespan.to_bits(),
                "{label}: makespan bits"
            );
            assert_eq!(fast.dropped, out.dropped, "{label}: drops");
            assert_eq!(fast.failed, out.failed, "{label}: failures");
            assert_eq!(fast.faults, out.faults, "{label}: fault summary");
            assert_eq!(log_fast, log, "{label}: fault-event log");
        }
    }
}
