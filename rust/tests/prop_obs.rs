//! Observability-plane invariants (randomized, seeded, replayable via
//! LAYERKV_PROP_SEED / LAYERKV_PROP_CASES — see util::prop):
//!
//! * bit-invisibility — attaching a tracer changes NOTHING about the
//!   simulation: merged records, makespan bits, drops, failures, fault
//!   summaries, rendered fault logs, per-replica routing, attribution
//!   and every engine counter are identical with tracing on vs off,
//!   across routers x macro-stepping x heap/lockstep drives x generated
//!   fault plans. Tracing is a pure observer, not a participant.
//! * well-formedness — the Chrome trace exported from any traced run
//!   passes `validate_chrome`: monotonic per-lane timestamps and every
//!   arrived request reaching a terminal mark (finish/drop/failed).
//! * bounded memory — the span/gauge rings never exceed their
//!   configured capacity; overflowing runs overwrite oldest-first and
//!   the (wrapped) export still validates.

use layerkv::cluster::{Cluster, ClusterConfig, FaultPlan, RouterPolicy};
use layerkv::config::{Policy, ServingConfig};
use layerkv::obs::export::{chrome_trace, validate_chrome};
use layerkv::obs::TraceHandle;
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn random_policy(rng: &mut Rng) -> Policy {
    match rng.range(0, 3) {
        0 => Policy::Vllm,
        1 => Policy::LayerKv { slo_aware: true },
        _ => Policy::LayerKv { slo_aware: false },
    }
}

fn random_trace(rng: &mut Rng, n: usize) -> Trace {
    let rate = rng.f64() * 4.0 + 0.5;
    let arrivals = if rng.chance(0.4) {
        Arrivals::bursty(rate, rng.f64() * 2.0 + 1.5)
    } else {
        Arrivals::Poisson { rate }
    };
    if rng.chance(0.5) {
        let mut w = ShareGptWorkload::paper(rate, n);
        w.arrivals = arrivals;
        w.generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals,
        }
        .generate(rng)
    }
}

#[test]
fn prop_tracing_is_bit_invisible() {
    prop(8, |rng| {
        let n = rng.range_usize(8, 30);
        // k=1 exercises the pure single-engine path too
        let k = rng.range_usize(1, 5);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let lockstep = rng.chance(0.5);
        let macro_steps = rng.chance(0.5);
        let trace = random_trace(rng, n);
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(random_policy(rng));
        let plan = if rng.chance(0.5) {
            let horizon = trace
                .requests
                .last()
                .map(|r| r.arrival)
                .unwrap_or(0.0)
                .max(1.0);
            Some(FaultPlan::generate(rng.range(0, 1 << 30) as u64, k, horizon * 1.3))
        } else {
            None
        };
        // per-instance handle (not the global sink): tests run in
        // parallel and must not observe each other's engines
        let run = |tracer: Option<&TraceHandle>| {
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
            if let Some(p) = &plan {
                cluster = cluster.with_faults(p.clone());
            }
            cluster.set_lockstep(lockstep);
            cluster.set_macro_steps(macro_steps);
            if let Some(h) = tracer {
                cluster.set_tracer(h.clone());
            }
            let out = cluster.run(&trace).expect("sim cluster never fails");
            let log: Vec<String> =
                cluster.fault_log().iter().map(|e| e.render()).collect();
            (out, log)
        };
        let handle = TraceHandle::new(1 << 16, 1 << 14);
        let (a, log_a) = run(Some(&handle));
        let (b, log_b) = run(None);
        let label = format!(
            "router {} k={k} lockstep={lockstep} macro={macro_steps} faulted={}",
            router.name(),
            plan.is_some()
        );
        assert_eq!(a.merged.records, b.merged.records, "{label}: records");
        assert_eq!(
            a.merged.makespan.to_bits(),
            b.merged.makespan.to_bits(),
            "{label}: makespan bits"
        );
        assert_eq!(a.dropped, b.dropped, "{label}: drops");
        assert_eq!(a.failed, b.failed, "{label}: failures");
        assert_eq!(a.faults, b.faults, "{label}: fault summary");
        assert_eq!(a.attribution, b.attribution, "{label}: attribution");
        assert_eq!(log_a, log_b, "{label}: rendered fault log");
        for (pa, pb) in a.per_replica.iter().zip(&b.per_replica) {
            assert_eq!(pa.routed, pb.routed, "{label}: routing diverged");
            assert_eq!(
                pa.report.records, pb.report.records,
                "{label}: per-replica records diverged"
            );
            // every engine counter identical — tracing reads state, it
            // never feeds back into scheduling or transfers
            assert_eq!(&pa.stats, &pb.stats, "{label}: engine stats diverged");
        }
        // attribution covers exactly the merged completions, in order
        assert_eq!(a.attribution.len(), a.merged.records.len(), "{label}");
        for (att, rec) in a.attribution.iter().zip(&a.merged.records) {
            assert_eq!(att.id, rec.id, "{label}: attribution order");
            assert!(att.replica < k, "{label}: replica index out of range");
            if plan.is_none() {
                assert_eq!(att.retries, 0, "{label}: retries on a fault-free run");
            }
        }
        // the traced run produced a well-formed, bounded trace
        let t = handle.lock();
        assert!(t.spans_len() <= t.span_capacity(), "{label}: span ring overflow");
        assert!(t.gauges_len() <= t.gauge_capacity(), "{label}: gauge ring overflow");
        if !a.merged.records.is_empty() {
            assert!(t.spans_len() > 0, "{label}: completions left no spans");
        }
        let doc = chrome_trace(&t);
        validate_chrome(&doc)
            .unwrap_or_else(|e| panic!("{label}: exported trace invalid: {e}"));
    });
}

/// A deliberately tiny ring under a run that emits far more events than
/// it can hold: memory stays bounded (oldest records overwritten, never
/// grown), behavior stays bit-identical, and the wrapped export still
/// validates (the lifecycle check downgrades, monotonicity holds).
#[test]
fn overflowing_ring_stays_bounded_and_invisible() {
    let cfg = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    let trace = FixedWorkload {
        prompt_len: 512,
        output_len: 64,
        n_requests: 64,
        arrivals: Arrivals::Poisson { rate: 4.0 },
    }
    .generate(&mut Rng::new(11));
    let ccfg = ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::KvPressure);
    let handle = TraceHandle::new(64, 32);
    let mut traced = Cluster::new(&ccfg);
    traced.set_tracer(handle.clone());
    let a = traced.run(&trace).expect("sim cluster run");
    let mut plain = Cluster::new(&ccfg);
    let b = plain.run(&trace).expect("sim cluster run");
    assert_eq!(a.merged.records, b.merged.records);
    assert_eq!(a.merged.makespan.to_bits(), b.merged.makespan.to_bits());
    let t = handle.lock();
    // 64 requests x (queued + prefill + per-token decode + finish) is
    // thousands of records: both rings must have wrapped, at capacity
    assert_eq!(t.spans_len(), t.span_capacity());
    assert!(t.spans_dropped() > 0, "span ring never wrapped");
    assert!(t.gauges_len() <= t.gauge_capacity());
    assert!(t.gauges_dropped() > 0, "gauge ring never wrapped");
    let summary = validate_chrome(&chrome_trace(&t)).expect("wrapped trace valid");
    assert!(summary.contains("ring wrapped"), "{summary}");
}

/// Crash-failover attribution: requests drained off a crashed replica
/// and finished elsewhere carry `retries > 0`, never attributed to the
/// replica that was down for the whole arrival window.
#[test]
fn attribution_tracks_failover_retries() {
    let cfg = ServingConfig::llama2_7b_tp1()
        .with_policy(Policy::LayerKv { slo_aware: true });
    let trace = FixedWorkload {
        prompt_len: 256,
        output_len: 128,
        n_requests: 40,
        arrivals: Arrivals::Poisson { rate: 4.0 },
    }
    .generate(&mut Rng::new(5));
    // replica 0 crashes at t=2s (its first routed requests, with ~5s of
    // decode ahead, are mid-flight) and stays down past the last arrival
    let plan = FaultPlan::parse_spec("crash=0@2:60,retries=3").expect("spec");
    let ccfg = ClusterConfig::homogeneous(&cfg, 3, RouterPolicy::RoundRobin);
    let mut cluster = Cluster::new(&ccfg).with_faults(plan);
    let out = cluster.run(&trace).expect("sim cluster run");
    assert_eq!(out.attribution.len(), out.merged.records.len());
    let moved: u64 = out.attribution.iter().map(|a| a.retries as u64).sum();
    assert!(moved > 0, "crash at t=2 must drain at least one in-flight request");
    let summary = out.faults.expect("faulted run has a summary");
    assert!(
        moved <= summary.retries,
        "completed-request retries ({moved}) exceed total failovers ({})",
        summary.retries
    );
    for a in &out.attribution {
        if a.retries > 0 {
            assert_ne!(
                a.replica, 0,
                "request {} retried onto the replica that was down",
                a.id
            );
        }
    }
    // fault-free control: same trace, nobody retries, per-replica
    // attribution counts reconcile with routed completions
    let mut plain = Cluster::new(&ccfg);
    let po = plain.run(&trace).expect("sim cluster run");
    assert!(po.attribution.iter().all(|a| a.retries == 0));
    let mut counts = vec![0usize; 3];
    for a in &po.attribution {
        counts[a.replica] += 1;
    }
    for (i, rep) in po.per_replica.iter().enumerate() {
        assert_eq!(counts[i], rep.report.records.len(), "replica {i}");
    }
}
