//! Prefix-cache invariants (randomized, seeded, replayable via
//! LAYERKV_PROP_SEED / LAYERKV_PROP_CASES — see util::prop; CI's
//! prop-deep job runs this suite at 512 cases):
//!
//! * generator determinism — `SessionWorkload` is a pure function of its
//!   seed: same seed, same trace, down to every prefix key;
//! * cache-off bit-identity — with `prefix_cache(false)` the engine is
//!   bit-identical to the frozen pre-refactor reference on session
//!   traces dense with prefix keys, and with the cache ON it stays
//!   bit-identical on traces that carry no keys — the cache must be
//!   unobservable unless both the flag and the keys are present;
//! * macro-stepping and routers stay invisible with the cache ON —
//!   cache ops only fire at admission/completion boundaries, which end
//!   macro spans, and a 1-replica cluster routes identically under
//!   every policy (including prefix-aware);
//! * conservation — session traces through a k-replica cluster under a
//!   random router: every request comes back exactly once, and the
//!   prefix counters stay internally consistent per replica.

#[path = "support/reference_engine.rs"]
mod reference_engine;

use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::{run_trace, standard_predictor, Engine, EngineStats};
use layerkv::util::prop::prop;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::{SessionWorkload, Trace};

fn random_session_workload(rng: &mut Rng) -> SessionWorkload {
    let mut w = SessionWorkload::chat(rng.range_usize(3, 14), rng.f64() * 1.5 + 0.3);
    if rng.chance(0.3) {
        w.shared_prefix_len = rng.range_usize(256, 3072);
    }
    if rng.chance(0.3) {
        w.mean_think_s = rng.f64() * 30.0 + 2.0;
    }
    w
}

fn session_trace(rng: &mut Rng) -> Trace {
    random_session_workload(rng).generate(rng)
}

/// A trace with NO prefix keys (every hash zero) — fixed or ShareGPT.
fn keyless_trace(rng: &mut Rng, n: usize) -> Trace {
    if rng.chance(0.5) {
        ShareGptWorkload::paper(rng.f64() * 4.0 + 0.5, n).generate(rng)
    } else {
        FixedWorkload {
            prompt_len: rng.range_usize(16, 4096),
            output_len: rng.range_usize(4, 128),
            n_requests: n,
            arrivals: Arrivals::Poisson { rate: rng.f64() * 3.0 + 0.2 },
        }
        .generate(rng)
    }
}

fn assert_stats_bit_identical(a: &EngineStats, b: &EngineStats, what: &str) {
    assert_eq!(
        (a.steps, a.prefill_steps, a.decode_steps, a.preemptions),
        (b.steps, b.prefill_steps, b.decode_steps, b.preemptions),
        "{what}: step counters diverge"
    );
    assert_eq!(
        (a.proactive_offload_layers, a.oom_forced_offload_layers, a.onloaded_layers),
        (b.proactive_offload_layers, b.oom_forced_offload_layers, b.onloaded_layers),
        "{what}: residency counters diverge"
    );
    assert_eq!(a.dropped, b.dropped, "{what}: dropped lists diverge");
    assert_eq!(a.offload_bytes.to_bits(), b.offload_bytes.to_bits(), "{what}: offload_bytes");
    assert_eq!(
        a.onload_stream_bytes.to_bits(),
        b.onload_stream_bytes.to_bits(),
        "{what}: onload_stream_bytes"
    );
    assert_eq!(a.stream_stall_s.to_bits(), b.stream_stall_s.to_bits(), "{what}: stream_stall_s");
    assert_eq!(a.contention_s.to_bits(), b.contention_s.to_bits(), "{what}: contention_s");
}

fn assert_prefix_counters_zero(s: &EngineStats, what: &str) {
    assert_eq!(
        (s.prefix_hits, s.prefix_misses, s.prefix_hit_tokens, s.prefix_inserts),
        (0, 0, 0, 0),
        "{what}: prefix counters must stay zero"
    );
    assert_eq!(
        (s.prefix_evictions, s.prefix_demotions, s.prefix_promotions),
        (0, 0, 0),
        "{what}: prefix movement counters must stay zero"
    );
    assert_eq!(s.prefix_restore_bytes.to_bits(), 0.0f64.to_bits(), "{what}: restore bytes");
}

#[test]
fn prop_session_generator_deterministic_per_seed() {
    prop(32, |rng| {
        let w = random_session_workload(rng);
        let seed = rng.next_u64();
        let a = w.generate(&mut Rng::new(seed));
        let b = w.generate(&mut Rng::new(seed));
        assert_eq!(a.requests, b.requests, "same seed must yield the same trace");
        a.validate().unwrap();
        // ids dense and arrival-ordered; every key 48-bit clean (survives
        // the JSON f64 round-trip) and never the reserved 0
        for (i, r) in a.requests.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(r.prefix.hash != 0 && r.prefix.hash < (1 << 48));
            assert!(r.prefix.publish != 0 && r.prefix.publish < (1 << 48));
            assert!(r.prefix.len <= r.prompt_len);
        }
    });
}

/// With the cache DISABLED the engine must be bit-identical to the frozen
/// pre-refactor reference even on traces dense with prefix keys: every
/// hook is gated on `cfg.prefix_cache` before it reads the key.
#[test]
fn prop_cache_off_bit_identical_to_reference_on_session_traces() {
    prop(6, |rng| {
        let trace = session_trace(rng);
        for policy in [
            Policy::Vllm,
            Policy::LayerKv { slo_aware: true },
            Policy::LayerKv { slo_aware: false },
        ] {
            let cfg = ServingConfig::llama2_7b_tp1()
                .with_policy(policy)
                .with_prefix_cache(false);
            let (rep, stats) = run_trace(cfg.clone(), &trace, 0.8);
            let (ref_rep, ref_stats) = reference_engine::run_trace_reference(cfg, &trace, 0.8);
            assert_eq!(rep.records, ref_rep.records, "{policy:?}: records diverge");
            assert_eq!(rep.makespan.to_bits(), ref_rep.makespan.to_bits());
            assert_stats_bit_identical(&stats, &ref_stats, &format!("{policy:?}"));
            assert_prefix_counters_zero(&stats, &format!("{policy:?} cache-off"));
        }
    });
}

/// With the cache ENABLED but the trace carrying no keys, the store never
/// populates and the engine stays bit-identical to the reference: the
/// pre-cache fleet (every existing trace, golden, and experiment) cannot
/// observe the feature.
#[test]
fn prop_cache_on_invisible_without_keys() {
    prop(6, |rng| {
        let n = rng.range_usize(5, 30);
        let trace = keyless_trace(rng, n);
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let cfg = ServingConfig::llama2_7b_tp1()
                .with_policy(policy)
                .with_prefix_cache(true);
            let (rep, stats) = run_trace(cfg.clone(), &trace, 0.8);
            let (ref_rep, ref_stats) = reference_engine::run_trace_reference(cfg, &trace, 0.8);
            assert_eq!(rep.records, ref_rep.records, "{policy:?}: records diverge");
            assert_eq!(rep.makespan.to_bits(), ref_rep.makespan.to_bits());
            assert_stats_bit_identical(&stats, &ref_stats, &format!("{policy:?}"));
            assert_prefix_counters_zero(&stats, &format!("{policy:?} keyless"));
        }
    });
}

/// Cache ON, session trace: decode fast-forwarding must stay bit-invisible
/// — cache ops (acquire at admission, publish at completion, demotion when
/// a queued head waits) all fire at scheduler boundaries, and the macro
/// path never skips one (it bails whenever the queue is non-empty).
#[test]
fn prop_macro_stepping_invisible_with_cache_on() {
    prop(6, |rng| {
        let trace = session_trace(rng);
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let cfg = ServingConfig::llama2_7b_tp1()
                .with_policy(policy)
                .with_prefix_cache(true);
            let predictor = standard_predictor(&trace, 0.8);

            let mut fast = Engine::new(cfg.clone(), predictor.clone());
            fast.set_macro_steps(true);
            fast.enable_transition_log();
            let rep_fast = fast.run(&trace);

            let mut slow = Engine::new(cfg, predictor);
            slow.set_macro_steps(false);
            slow.enable_transition_log();
            let rep_slow = slow.run(&trace);

            assert_eq!(rep_fast.records, rep_slow.records, "{policy:?}: records diverge");
            assert_eq!(rep_fast.makespan.to_bits(), rep_slow.makespan.to_bits());
            assert_eq!(fast.stats(), slow.stats(), "{policy:?}: stats diverge");
            assert_eq!(
                fast.take_transitions(),
                slow.take_transitions(),
                "{policy:?}: transition logs diverge"
            );
        }
    });
}

/// A 1-replica cluster routes identically under every policy — including
/// prefix-aware, whose affinity score cannot change a single-candidate
/// argmax — so the whole incremental drive must reproduce `run_trace`
/// bit-for-bit with the cache ON and keys present.
#[test]
fn prop_single_replica_identity_with_cache_on_across_routers() {
    prop(4, |rng| {
        let trace = session_trace(rng);
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_prefix_cache(true);
        let (bare, bare_stats) = run_trace(cfg.clone(), &trace, 0.8);
        for router in RouterPolicy::ALL {
            let ccfg = ClusterConfig {
                replicas: vec![cfg.clone()],
                router: *router,
                predictor_accuracy: 0.8,
            };
            let mut cluster = Cluster::new(&ccfg);
            let out = cluster.run(&trace).expect("sim cluster never fails");
            assert_eq!(
                out.merged.records,
                bare.records,
                "router {}: records diverge from the bare engine",
                router.name()
            );
            assert_eq!(out.merged.makespan.to_bits(), bare.makespan.to_bits());
            assert_eq!(
                &out.per_replica[0].stats,
                &bare_stats,
                "router {}: engine stats (incl. prefix counters) diverge",
                router.name()
            );
        }
    });
}

/// Session traces through a k-replica cluster, cache ON, random router:
/// conservation holds regardless of hit rate, and the per-replica prefix
/// counters stay internally consistent.
#[test]
fn prop_session_cluster_conserves_and_counters_consistent() {
    prop(6, |rng| {
        let trace = session_trace(rng);
        let n = trace.requests.len();
        let k = rng.range_usize(1, 5);
        let router = RouterPolicy::ALL[rng.range_usize(0, RouterPolicy::ALL.len())];
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_prefix_cache(true);
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
        let out = cluster.run(&trace).expect("sim cluster never fails");
        assert_eq!(
            out.per_replica.iter().map(|o| o.routed).sum::<usize>(),
            n,
            "router {} on {k} replicas lost/duplicated a routing",
            router.name()
        );
        let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
        ids.extend(out.dropped.iter().copied());
        ids.sort_unstable();
        assert_eq!(
            ids,
            (0..n).collect::<Vec<_>>(),
            "router {}: completions + drops must be a permutation of the trace",
            router.name()
        );
        for (i, o) in out.per_replica.iter().enumerate() {
            let s = &o.stats;
            // an acquire happens at most once per prefill pass: one per
            // routed request plus one per preemption-forced re-prefill;
            // and hit tokens only exist where hits do
            assert!(
                s.prefix_hits + s.prefix_misses <= o.routed as u64 + s.preemptions,
                "replica {i}: more lookups than prefill passes"
            );
            if s.prefix_hits == 0 {
                assert_eq!(s.prefix_hit_tokens, 0, "replica {i}: phantom hit tokens");
            }
            // the store can never evict more than was ever inserted
            assert!(
                s.prefix_evictions <= s.prefix_inserts,
                "replica {i}: evicted {} of only {} inserts",
                s.prefix_evictions,
                s.prefix_inserts
            );
            // restores are host/disk hits only — absent hits, no bytes
            if s.prefix_hits == 0 {
                assert_eq!(s.prefix_restore_bytes.to_bits(), 0.0f64.to_bits());
            }
        }
    });
}
