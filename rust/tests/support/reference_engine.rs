//! The PRE-REFACTOR simulation engine, preserved verbatim as the
//! reference for the `ExecutionBackend` refactor: a monolithic
//! continuous-batching loop with the cost-model arithmetic inlined,
//! exactly as `coordinator/engine.rs` stood before the engine went
//! generic over its executor.
//!
//! `prop_unified_engine_matches_pre_refactor_reference` asserts
//! `Engine<SimBackend>` reproduces this engine's reports and stats
//! bit-for-bit across randomized traces under every policy. Do not
//! "improve" this file — its value is that it does not change.

#![allow(dead_code, clippy::needless_range_loop)]

use std::collections::VecDeque;

use layerkv::config::{Fabric, Policy, ServingConfig};
use layerkv::coordinator::block::{KvError, KvManager, Residency};
use layerkv::coordinator::predict::LengthPredictor;
use layerkv::coordinator::request::{Phase, ReqId, Request};
use layerkv::coordinator::scheduler::{make_scheduler, Action, SchedContext, Scheduler};
use layerkv::coordinator::EngineStats;
use layerkv::metrics::{Report, RequestRecord};
use layerkv::sim::CostModel;
use layerkv::workload::Trace;

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct RunningAggregates {
    resident_count: usize,
    resident_tokens: usize,
}

impl RunningAggregates {
    fn recompute(running: &[ReqId], requests: &[Request], kv: &KvManager) -> Self {
        let mut a = RunningAggregates::default();
        for &rid in running {
            if kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false) {
                a.resident_count += 1;
                a.resident_tokens += requests[rid].context_len();
            }
        }
        a
    }
}

/// The pre-refactor engine, field for field.
pub struct ReferenceEngine {
    pub cfg: ServingConfig,
    pub cost: CostModel,
    pub kv: KvManager,
    scheduler: Box<dyn Scheduler>,
    predictor: LengthPredictor,
    requests: Vec<Request>,
    waiting: VecDeque<ReqId>,
    running: Vec<ReqId>,
    now: f64,
    stats: EngineStats,
    records: Vec<RequestRecord>,
    agg: RunningAggregates,
    incremental: bool,
    restore_threshold: usize,
    active_buf: Vec<ReqId>,
    finished_buf: Vec<ReqId>,
}

impl ReferenceEngine {
    pub fn new(cfg: ServingConfig, predictor: LengthPredictor) -> Self {
        let cost = CostModel::new(cfg.clone());
        let kv = KvManager::new(
            cfg.num_gpu_layer_blocks(),
            cfg.num_cpu_layer_blocks(),
            cfg.block_size,
            cfg.model.n_layers,
        );
        let scheduler = make_scheduler(&cfg);
        let restore_threshold =
            (cfg.avail_threshold_frac * kv.gpu.total() as f64) as usize;
        ReferenceEngine {
            cfg,
            cost,
            kv,
            scheduler,
            predictor,
            requests: Vec::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            now: 0.0,
            stats: EngineStats::default(),
            records: Vec::new(),
            agg: RunningAggregates::default(),
            incremental: true,
            restore_threshold,
            active_buf: Vec::new(),
            finished_buf: Vec::new(),
        }
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    pub fn use_recompute_oracle(&mut self) {
        self.incremental = false;
    }

    pub fn run(&mut self, trace: &Trace) -> Report {
        self.requests = trace
            .requests
            .iter()
            .map(|t| Request::from_trace(t, self.predictor.predict(t.id, t.output_len)))
            .collect();
        self.agg = RunningAggregates::default();
        let mut next_arrival = 0usize;
        let max_steps = 1000 + 4 * trace.total_tokens() as u64;

        loop {
            while next_arrival < self.requests.len()
                && self.requests[next_arrival].arrival <= self.now + 1e-12
            {
                self.waiting.push_back(next_arrival);
                next_arrival += 1;
            }

            self.oracle_refresh();

            let action = {
                let waiting = self.waiting.make_contiguous();
                let ctx = SchedContext {
                    now: self.now,
                    waiting,
                    running: &self.running,
                    requests: &self.requests,
                    kv: &self.kv,
                    cost: &self.cost,
                    cfg: &self.cfg,
                };
                self.scheduler.decide(&ctx)
            };

            match action {
                Action::Prefill(reqs) => self.step_prefill(&reqs),
                Action::Decode => self.step_decode(),
                Action::Wait => {
                    if let Some(&r) = self.waiting.front() {
                        if self.never_fits(r) {
                            self.waiting.pop_front();
                            self.stats.dropped.push(r);
                            self.requests[r].phase = Phase::Finished;
                            continue;
                        }
                    }
                    if next_arrival < self.requests.len() {
                        self.now = self.requests[next_arrival].arrival.max(self.now);
                        continue;
                    }
                    if self.running.is_empty() && self.waiting.is_empty() {
                        break;
                    }
                    if self.running.is_empty() && next_arrival >= self.requests.len() {
                        let r = self.waiting.pop_front().unwrap();
                        self.stats.dropped.push(r);
                        self.requests[r].phase = Phase::Finished;
                    }
                }
            }

            self.stats.steps += 1;
            if self.stats.steps > max_steps {
                panic!(
                    "engine exceeded {max_steps} steps ({} waiting, {} running) — livelock",
                    self.waiting.len(),
                    self.running.len()
                );
            }
        }
        Report::new(std::mem::take(&mut self.records))
    }

    fn never_fits(&self, r: ReqId) -> bool {
        let len = self.requests[r].prefill_len();
        let per_layer = len.div_ceil(self.cfg.block_size);
        match self.cfg.policy {
            Policy::Vllm => per_layer * self.cfg.model.n_layers > self.kv.gpu.total(),
            Policy::LayerKv { .. } => {
                let x = self.cost.min_resident_layers(len);
                per_layer * x > self.kv.gpu.total()
                    || per_layer * (self.cfg.model.n_layers - x) > self.kv.cpu.total()
            }
        }
    }

    fn oracle_refresh(&mut self) {
        if self.incremental {
            return;
        }
        let reqs = &self.requests;
        self.running.sort_by(|&a, &b| {
            let ta = reqs[a].prefill_start.unwrap_or(0.0);
            let tb = reqs[b].prefill_start.unwrap_or(0.0);
            ta.partial_cmp(&tb).unwrap()
        });
        self.agg = RunningAggregates::recompute(&self.running, &self.requests, &self.kv);
    }

    fn agg_admit(&mut self, rid: ReqId) {
        if self.incremental
            && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
        {
            self.agg.resident_count += 1;
            self.agg.resident_tokens += self.requests[rid].context_len();
        }
    }

    fn agg_remove(&mut self, rid: ReqId) {
        if self.incremental
            && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
        {
            self.agg.resident_count -= 1;
            self.agg.resident_tokens -= self.requests[rid].context_len();
        }
    }

    fn kv_offload(&mut self, rid: ReqId, layer: usize) -> Result<usize, KvError> {
        let was_resident =
            self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false);
        let out = self.kv.offload_layer(rid, layer);
        if self.incremental {
            if let Ok(n) = out {
                if n > 0 && was_resident {
                    self.agg.resident_count -= 1;
                    self.agg.resident_tokens -= self.requests[rid].context_len();
                }
            }
        }
        out
    }

    fn kv_onload(&mut self, rid: ReqId, layer: usize) -> Result<usize, KvError> {
        let out = self.kv.onload_layer(rid, layer);
        if self.incremental {
            if let Ok(n) = out {
                if n > 0
                    && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
                {
                    self.agg.resident_count += 1;
                    self.agg.resident_tokens += self.requests[rid].context_len();
                }
            }
        }
        out
    }

    fn step_prefill(&mut self, reqs: &[(ReqId, usize)]) {
        let mut duration = 0.0;
        let mut offload_bytes = 0.0;
        let l = self.cfg.model.n_layers;
        for &(rid, x) in reqs {
            let len = self.requests[rid].prefill_len();
            let alloc = match self.cfg.policy {
                Policy::Vllm => self.kv.allocate_full(rid, len),
                Policy::LayerKv { .. } => self.kv.allocate_layerwise(rid, len, x),
            };
            if alloc.is_err() {
                continue;
            }
            offload_bytes += len as f64
                * (l - x.min(l)) as f64
                * self.cfg.offload_bytes_per_token_layer()
                / self.cfg.tp as f64;

            if self.waiting.front() == Some(&rid) {
                self.waiting.pop_front();
            } else if let Some(pos) = self.waiting.iter().position(|&w| w == rid) {
                self.waiting.remove(pos);
            }
            let r = &mut self.requests[rid];
            if r.prefill_start.is_none() {
                r.prefill_start = Some(self.now);
            }
            duration += self.cost.prefill_time(len);
            r.preemptions += matches!(r.phase, Phase::Preempted) as usize;
            r.phase = Phase::Decoding;
            let ps = self.requests[rid].prefill_start.unwrap();
            let reqs_ref = &self.requests;
            let pos = self
                .running
                .partition_point(|&o| reqs_ref[o].prefill_start.unwrap_or(0.0) <= ps);
            self.running.insert(pos, rid);
            self.agg_admit(rid);
        }
        self.stats.offload_bytes += offload_bytes;
        self.now += duration;
        self.stats.prefill_steps += 1;

        for &(rid, _) in reqs {
            if self.requests[rid].phase == Phase::Decoding
                && self.requests[rid].first_token.is_none()
            {
                self.requests[rid].first_token = Some(self.now);
                self.requests[rid].generated = 1;
                if self.incremental
                    && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
                {
                    self.agg.resident_tokens += 1;
                }
                if self.requests[rid].done() {
                    self.complete(rid);
                }
            }
        }
    }

    fn step_decode(&mut self) {
        debug_assert!(!self.running.is_empty());

        if matches!(self.cfg.policy, Policy::LayerKv { .. }) {
            self.restore_layers();
        }
        if !self.incremental {
            self.agg =
                RunningAggregates::recompute(&self.running, &self.requests, &self.kv);
        }

        let mut active = std::mem::take(&mut self.active_buf);
        active.clear();
        let mut stream_bytes = 0.0;
        let (batch, total_ctx) = if self.agg.resident_count > 0 {
            active.extend(self.running.iter().copied().filter(|&r| {
                self.kv.table(r).map(|t| t.fully_resident()).unwrap_or(false)
            }));
            debug_assert_eq!(active.len(), self.agg.resident_count);
            (self.agg.resident_count, self.agg.resident_tokens)
        } else {
            let oldest = *self.running.first().expect("running nonempty");
            if let Some(t) = self.kv.table(oldest) {
                stream_bytes = t.n_cpu_layers() as f64
                    * t.tokens as f64
                    * self.cfg.offload_bytes_per_token_layer()
                    / self.cfg.tp as f64;
            }
            active.push(oldest);
            (1, self.requests[oldest].context_len())
        };

        let compute = self.cost.decode_step_time_sum(total_ctx, batch);
        let stream_time = if stream_bytes > 0.0 {
            stream_bytes / self.cost.pcie_bw_per_gpu() + self.cfg.node.pcie.latency
        } else {
            0.0
        };
        let mut step = compute.max(stream_time);
        self.stats.stream_stall_s += (stream_time - compute).max(0.0);
        self.stats.onload_stream_bytes += stream_bytes;

        if self.cfg.tp > 1 && self.cfg.node.fabric == Fabric::Pcie && stream_bytes > 0.0 {
            let ar = self.cost.allreduce_time(batch);
            let penalty = if self.cfg.pcie_chunking { 0.05 * ar } else { ar.min(stream_time) };
            step += penalty;
            self.stats.contention_s += penalty;
        }

        self.now += step;
        self.stats.decode_steps += 1;
        self.scheduler.observe_decode_step(step);

        let mut finished = std::mem::take(&mut self.finished_buf);
        finished.clear();
        for &rid in &active {
            match self.kv.append_token(rid) {
                Ok(()) => {}
                Err(KvError::GpuExhausted) => {
                    if !self.relieve_gpu_pressure(rid) {
                        continue;
                    }
                    if self.kv.append_token(rid).is_err() {
                        continue;
                    }
                }
                Err(KvError::CpuExhausted) => continue,
                Err(KvError::UnknownRequest) => continue,
            }
            if self.requests[rid].phase != Phase::Decoding {
                continue;
            }
            self.requests[rid].generated += 1;
            if self.incremental
                && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
            {
                self.agg.resident_tokens += 1;
            }
            if self.requests[rid].done() {
                finished.push(rid);
            }
        }
        for &rid in &finished {
            self.complete(rid);
        }
        finished.clear();
        self.finished_buf = finished;
        active.clear();
        self.active_buf = active;

        let plan = {
            let waiting = self.waiting.make_contiguous();
            let ctx = SchedContext {
                now: self.now,
                waiting,
                running: &self.running,
                requests: &self.requests,
                kv: &self.kv,
                cost: &self.cost,
                cfg: &self.cfg,
            };
            self.scheduler.proactive_offloads(&ctx)
        };
        for (rid, layer) in plan {
            if let Ok(n) = self.kv_offload(rid, layer) {
                if n > 0 {
                    self.stats.proactive_offload_layers += 1;
                    self.stats.offload_bytes += n as f64
                        * self.cfg.block_size as f64
                        * self.cfg.offload_bytes_per_token_layer()
                        / self.cfg.tp as f64;
                }
            }
        }
    }

    fn relieve_gpu_pressure(&mut self, needy: ReqId) -> bool {
        match self.cfg.policy {
            Policy::LayerKv { .. } => {
                let need = self.requests[needy].context_len() / self.cfg.block_size + 1;
                let n_layers = self.cfg.model.n_layers;
                let mut freed = 0usize;
                for pass in 0..2 {
                    for vi in (0..self.running.len()).rev() {
                        let v = self.running[vi];
                        let Some(t) = self.kv.table(v) else { continue };
                        let resident = t.n_gpu_layers();
                        if resident == 0 {
                            continue;
                        }
                        let take = if pass == 0 { resident / 2 } else { resident };
                        let mut taken = 0usize;
                        for layer in 0..n_layers {
                            if taken >= take {
                                break;
                            }
                            let Some(t) = self.kv.table(v) else { break };
                            if t.layers[layer].residency != Residency::Gpu {
                                continue;
                            }
                            if freed >= need {
                                return true;
                            }
                            taken += 1;
                            if let Ok(n) = self.kv_offload(v, layer) {
                                freed += n;
                                self.stats.oom_forced_offload_layers += 1;
                            }
                        }
                    }
                    if freed >= need {
                        return true;
                    }
                }
                freed > 0
            }
            Policy::Vllm => {
                // One deliberate backport (the sole divergence from the
                // pre-refactor file): skip victims that already finished
                // this step, mirroring the double-serve fix in
                // coordinator/engine.rs so the bit-identity property
                // keeps comparing like with like.
                let reqs = &self.requests;
                let victim = self
                    .running
                    .iter()
                    .rev()
                    .copied()
                    .find(|&r| r != needy && !reqs[r].done())
                    .or(Some(needy));
                match victim {
                    Some(v) => {
                        self.preempt_recompute(v);
                        true
                    }
                    None => false,
                }
            }
        }
    }

    fn preempt_recompute(&mut self, rid: ReqId) {
        self.agg_remove(rid);
        let _ = self.kv.release(rid);
        self.running.retain(|&r| r != rid);
        self.requests[rid].phase = Phase::Preempted;
        self.waiting.push_front(rid);
        self.stats.preemptions += 1;
    }

    fn restore_layers(&mut self) {
        if self.kv.cpu.used() == 0 {
            return;
        }
        let threshold = self.restore_threshold;
        let n_layers = self.cfg.model.n_layers;
        for i in 0..self.running.len() {
            let rid = self.running[i];
            for layer in 0..n_layers {
                let Some(t) = self.kv.table(rid) else { break };
                if t.layers[layer].residency != Residency::Cpu {
                    continue;
                }
                let per_layer = t.blocks_per_layer(t.tokens).max(1);
                if self.kv.gpu.available() < threshold + per_layer {
                    return;
                }
                match self.kv_onload(rid, layer) {
                    Ok(n) if n > 0 => self.stats.onloaded_layers += 1,
                    _ => return,
                }
            }
        }
    }

    fn complete(&mut self, rid: ReqId) {
        self.agg_remove(rid);
        let _ = self.kv.release(rid);
        self.running.retain(|&r| r != rid);
        let r = &mut self.requests[rid];
        r.phase = Phase::Finished;
        r.finish = Some(self.now);
        self.records.push(RequestRecord {
            id: r.id,
            arrival: r.arrival,
            prefill_start: r.prefill_start.unwrap_or(r.arrival),
            first_token: r.first_token.unwrap_or(self.now),
            finish: self.now,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
        });
    }
}

fn run_reference_with(
    cfg: ServingConfig,
    trace: &Trace,
    predictor_accuracy: f64,
    oracle: bool,
) -> (Report, EngineStats) {
    let predictor = LengthPredictor::new(
        trace.requests.iter().map(|r| r.output_len).max().unwrap_or(1024).max(2),
        predictor_accuracy,
        42,
    );
    let mut engine = ReferenceEngine::new(cfg, predictor);
    if oracle {
        engine.use_recompute_oracle();
    }
    let report = engine.run(trace);
    let stats = engine.stats().clone();
    (report, stats)
}

/// `run_trace`, pre-refactor edition — identical predictor setup.
pub fn run_trace_reference(
    cfg: ServingConfig,
    trace: &Trace,
    predictor_accuracy: f64,
) -> (Report, EngineStats) {
    run_reference_with(cfg, trace, predictor_accuracy, false)
}

/// `run_trace_oracle`, pre-refactor edition.
pub fn run_trace_reference_oracle(
    cfg: ServingConfig,
    trace: &Trace,
    predictor_accuracy: f64,
) -> (Report, EngineStats) {
    run_reference_with(cfg, trace, predictor_accuracy, true)
}
