//! Offline stand-in for the `anyhow` crate, covering exactly the API
//! surface this workspace uses: `Error`, `Result`, the `anyhow!`/`bail!`/
//! `ensure!` macros, and the `Context` extension trait on `Result` and
//! `Option`. Error values carry an optional chain of context strings that
//! `{:#}` formatting renders `outer: inner` like the real crate.

use std::fmt;

/// Boxed dynamic error with prepended context layers.
pub struct Error {
    context: Vec<String>,
    source: Box<dyn std::error::Error + Send + Sync + 'static>,
}

impl Error {
    /// Build from any error type (what `?` conversions go through).
    pub fn new<E>(source: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { context: Vec::new(), source: Box::new(source) }
    }

    /// Build from a displayable message (`anyhow!("...")`).
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error { context: Vec::new(), source: Box::new(Message(message.to_string())) }
    }

    /// Prepend a context layer (outermost first in display).
    pub fn context<C>(mut self, context: C) -> Self
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.context.insert(0, context.to_string());
        self
    }

    /// The outermost description (context if any, else the source).
    fn headline(&self) -> String {
        match self.context.first() {
            Some(c) => c.clone(),
            None => self.source.to_string(),
        }
    }

    /// Every layer, outermost first: contexts, then the error chain.
    fn layers(&self) -> Vec<String> {
        let mut out = self.context.clone();
        out.push(self.source.to_string());
        let mut cause = self.source.source();
        while let Some(c) = cause {
            out.push(c.to_string());
            cause = c.source();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-joined (anyhow's format)
            write!(f, "{}", self.layers().join(": "))
        } else {
            write!(f, "{}", self.headline())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let layers = self.layers();
        write!(f, "{}", layers[0])?;
        if layers.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for l in &layers[1..] {
                write!(f, "\n    {l}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// Message-only error payload for `anyhow!`/`bail!`.
#[derive(Debug)]
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Message {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error/none arm of a `Result` or `Option`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt", args..)` or `anyhow!(displayable_value)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an `anyhow!` error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert-or-bail.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn context_layers_render_outermost_first() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "opening config".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        let e = anyhow!(String::from("owned message"));
        assert_eq!(format!("{e}"), "owned message");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 2);
            ensure!(false, "bad {}", "news");
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "bad news");
    }
}
