//! Stub of the `xla` PJRT bindings (API-compatible with the subset
//! `runtime/client.rs` uses). The real crate links libxla/PJRT, which is
//! not present in the offline build environment; this stub lets the whole
//! workspace compile and run the simulation/experiment paths, while any
//! attempt to actually create a PJRT client fails with a clear error.
//! The callers all guard the PJRT path behind an artifacts-manifest check,
//! so the simulation binaries never hit these errors.

use std::fmt;

/// Error every stubbed operation returns.
#[derive(Debug)]
pub struct XlaError(pub String);

impl XlaError {
    fn unavailable(what: &str) -> Self {
        XlaError(format!(
            "{what}: xla/PJRT runtime not available in this build (stub crate; \
             install the real `xla` bindings to run compiled artifacts)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Device buffer handle (never constructible through the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("to_literal_sync"))
    }
}

/// Host-side literal value.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable("to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("to_vec"))
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable(&format!("parsing HLO text {path}")))
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("execute_b"))
    }
}

/// PJRT client handle. `cpu()` fails in the stub — the one entry point.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("x.hlo.txt"));
    }
}
